//! `mrapriori` CLI — launcher for mining runs, dataset generation,
//! benchmark sweeps, and cost-model calibration.

use anyhow::{bail, Result};
use mrapriori::bench_harness::tables::{self, FaultScenario, ScaleRun, SweepSpec};
use mrapriori::cluster::{ClusterConfig, FaultModel};
use mrapriori::coordinator::{
    mappers::GenMode, Algorithm, CancelToken, CountingBackend, MiningError, MiningOutcome,
    MiningRequest, MiningSession, PhaseEvent, RunOptions,
};
use mrapriori::dataset::ibm::QuestGen;
use mrapriori::dataset::{loader, registry, stats};
use mrapriori::hdfs;
use mrapriori::util::flags::FlagSet;
use mrapriori::util::logging::{self, Level};
use std::path::{Path, PathBuf};

/// Default generate-to-disk cache for Quest-family datasets and segment
/// imports (under cargo's target dir, so it never pollutes the tree).
const DEFAULT_CACHE: &str = "target/dataset-cache";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd {
        "mine" => cmd_mine(rest),
        "serve" => cmd_serve(rest),
        "inspect" => cmd_inspect(rest),
        "generate" => cmd_generate(rest),
        "sweep" => cmd_sweep(rest),
        "calibrate" => cmd_calibrate(rest),
        "lk" => cmd_lk(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `mrapriori help`"),
    }
}

fn print_help() {
    println!(
        "mrapriori — MapReduce-based Apriori on a simulated Hadoop cluster

Commands:
  mine       run one algorithm (or --algo all) on a dataset, print phase breakdown
  serve      TCP mining daemon: MINE/STATS/PING/SHUTDOWN line protocol
  sweep      paper's Figs 2-4 min_sup sweep, or a scale grid (--datasets)
  lk         print the |L_k| profile (paper Table 6) via the oracle
  inspect    dataset summary statistics (paper Table 2)
  generate   write a dataset to a FIMI text file or segment store
  calibrate  fit cost-model weights against the paper's Table 3
  help       this message

Datasets: registry names (c20d10k, chess, mushroom), Quest-family names
(t<T>i<I>d<D>, e.g. t10i4d100k or t40i10d1m — generated to a disk cache
on first use), or FIMI file paths. `--streamed` mines through the
out-of-core segment store; memory stays bounded by the block size.

Run `mrapriori <command> --help` for flags."
    );
}

fn common_cluster(p: &mrapriori::util::flags::Parsed) -> Result<ClusterConfig> {
    let mut cluster = match p.get("cluster-config") {
        Some(path) => mrapriori::config::load_cluster(std::path::Path::new(path))?,
        None => ClusterConfig::paper_cluster(),
    };
    if let Some(n) = p.usize("data-nodes")? {
        let slots = cluster.nodes.first().map(|n| n.map_slots).unwrap_or(4);
        cluster = ClusterConfig::uniform(n, slots);
    }
    if let Some(w) = p.usize("workers")? {
        cluster.workers = w;
    }
    Ok(cluster)
}

/// Resolve a dataset name through [`registry::try_load`] (never the
/// panicking [`registry::load`]): unknown names come back as a clean error
/// listing the known registry datasets, and the process exits 1 without a
/// backtrace.
fn unknown_dataset(name: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "unknown dataset {name:?}: not a registry dataset (known: {}), not a Quest-family \
         name (t<T>i<I>d<D>, e.g. {}), and not a readable file",
        registry::NAMES.join(", "),
        registry::QUEST_NAMES.join(", ")
    )
}

fn resolve_db(name: &str) -> Result<mrapriori::dataset::TransactionDb> {
    if let Some(db) = registry::try_load(name) {
        return Ok(db);
    }
    let path = Path::new(name);
    if path.exists() {
        return Ok(loader::load_file(path)?);
    }
    Err(unknown_dataset(name))
}

/// Resolve `--dataset` via [`resolve_db`].
fn load_db(p: &mrapriori::util::flags::Parsed) -> Result<mrapriori::dataset::TransactionDb> {
    resolve_db(p.required("dataset")?)
}

/// The `--cache-dir` for generated/imported segment stores.
fn cache_dir(p: &mrapriori::util::flags::Parsed) -> PathBuf {
    PathBuf::from(p.get("cache-dir").unwrap_or(DEFAULT_CACHE))
}

/// Build the [`FaultModel`] of the `--fail-prob`/`--straggler-prob`/
/// `--speculation` flags; `None` when no fault flag was given (the clean
/// path stays the default). Domain validation happens at the session
/// layer, as a typed [`MiningError`].
fn fault_model_from_flags(p: &mrapriori::util::flags::Parsed) -> Result<Option<FaultModel>> {
    let fail_prob = p.f64("fail-prob")?;
    let straggler_prob = p.f64("straggler-prob")?;
    let speculation = p.bool("speculation");
    if fail_prob.is_none() && straggler_prob.is_none() && !speculation {
        return Ok(None);
    }
    Ok(Some(FaultModel {
        fail_prob: fail_prob.unwrap_or(0.0),
        straggler_prob: straggler_prob.unwrap_or(0.0),
        speculation,
        ..Default::default()
    }))
}

/// Run one query, streaming live phase-finished lines to stderr when
/// `verbose` (with an optional `[algo]` prefix for multi-algorithm runs).
fn run_with_live_events(
    session: &MiningSession,
    req: &MiningRequest,
    verbose: bool,
    label: Option<&str>,
) -> std::result::Result<MiningOutcome, MiningError> {
    if !verbose {
        return session.run(req);
    }
    session.run_streaming(req, &CancelToken::new(), |ev| {
        if let PhaseEvent::PhaseFinished { record, from_cache } = ev {
            let backend = record.backend_label();
            eprintln!(
                "  {}phase {} ({}) finished: {:.1} s simulated{}{}",
                label.map(|l| format!("[{l}] ")).unwrap_or_default(),
                record.phase,
                record.job,
                record.elapsed,
                if backend == "-" { String::new() } else { format!(" [{backend}]") },
                if from_cache { " [job1 cache]" } else { "" }
            );
        }
    })
}

/// Cache slot for a file import: the store directory is keyed by the
/// file's canonical path only (stable across edits, so re-imports replace
/// in place and the cache holds at most one copy per source file), while a
/// `.fingerprint` sidecar records size + mtime to detect staleness.
///
/// The digest is SipHash-1-3 under the crate's pinned zero key
/// (`util::siphash`), fed the canonical path's lossy-UTF-8 bytes directly
/// rather than via `Path::hash` — the latter's byte feed is a std
/// implementation detail, so dir names would silently change across
/// toolchains. Slots minted by older builds under `DefaultHasher`-derived
/// names are simply orphaned in the cache: nothing reads or deletes them,
/// and the fingerprint sidecar repopulates the new slot on first use.
fn import_cache_entry(cache: &Path, path: &Path) -> (PathBuf, PathBuf, String) {
    use std::hash::Hasher as _;
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset");
    let canon = path.canonicalize().unwrap_or_else(|_| path.to_path_buf());
    let mut h = mrapriori::util::siphash::SipHasher13::new();
    h.write(canon.to_string_lossy().as_bytes());
    let dir = cache.join(format!("import-{stem}-{:016x}", h.finish()));
    let fingerprint = std::fs::metadata(path)
        .map(|m| format!("{} {:?}", m.len(), m.modified().ok()))
        .unwrap_or_default();
    let mut fp = dir.as_os_str().to_os_string();
    fp.push(".fingerprint");
    (dir, PathBuf::from(fp), fingerprint)
}

/// Resolve a dataset name into a segment-store-backed HDFS file — the
/// out-of-core path. Quest-family names generate to the cache on first
/// use; FIMI file paths are imported into the cache (keyed by path + size,
/// reused when present); registry names are materialized once and written
/// through (reused when the cached length matches).
fn streamed_file(
    name: &str,
    cache: &Path,
    cluster: &ClusterConfig,
    seed: u64,
) -> Result<hdfs::HdfsFile> {
    use anyhow::Context as _;
    use mrapriori::hdfs::segment;
    let n_nodes = cluster.nodes.len();
    let put = |src: segment::SegmentSource| {
        hdfs::put_segmented(std::sync::Arc::new(src), n_nodes, hdfs::DEFAULT_REPLICATION, seed)
    };
    if registry::quest_params(name).is_some() {
        let src = registry::quest_store(name, cache)
            .with_context(|| format!("building quest store for {name:?}"))?;
        return Ok(put(src));
    }
    // Registry names resolve before file paths, exactly like [`resolve_db`]
    // — `--streamed` must never change WHICH dataset a name denotes.
    if let Some(db) = registry::try_load(name) {
        let dir = cache.join(&db.name);
        if segment::exists(&dir) {
            let src = segment::open(&dir)?;
            if src.len() == db.len() {
                return Ok(put(src));
            }
        }
        let src = segment::write_store(
            &dir,
            db.name.as_str(),
            registry::split_lines(&db.name),
            db.n_items,
            db.txns.iter().cloned(),
        )
        .with_context(|| format!("writing store for {name:?}"))?;
        return Ok(put(src));
    }
    let path = Path::new(name);
    if path.exists() {
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset");
        let (dir, fp_path, fingerprint) = import_cache_entry(cache, path);
        let fresh = !fingerprint.is_empty()
            && std::fs::read_to_string(&fp_path).is_ok_and(|s| s == fingerprint);
        if segment::exists(&dir) && fresh {
            return Ok(put(segment::open(&dir)?));
        }
        let src = loader::import_segmented(path, &dir, registry::split_lines(stem))
            .with_context(|| format!("importing {name:?} into {dir:?}"))?;
        std::fs::write(&fp_path, &fingerprint)?;
        return Ok(put(src));
    }
    Err(unknown_dataset(name))
}

fn cmd_mine(args: &[String]) -> Result<()> {
    let set = FlagSet::new("mine", "run one algorithm (or --algo all) on a dataset")
        .opt("dataset", "registry name, t<T>i<I>d<D> Quest name, or FIMI file path")
        .opt("algo", "algorithm: spc|fpc|dpc|vfpc|etdpc|opt-vfpc|opt-etdpc, or `all`")
        .opt("min-sup", "fractional minimum support (default: paper reference)")
        .opt("split-lines", "lines per input split (default: paper setting)")
        .opt("fpc-n", "FPC passes per phase (default 3)")
        .opt("dpc-alpha", "DPC candidate-budget alpha (default: paper per-dataset)")
        .opt("dpc-beta", "DPC elapsed-time beta, seconds (default 60)")
        .opt("cluster-config", "TOML cluster config path")
        .opt("data-nodes", "override: uniform cluster of N DataNodes")
        .opt("workers", "host threads for real execution")
        .opt_default("gen-mode", "per-record", "per-record|per-task generation cost")
        .opt("backend", "Job2 counting backend: trie|bitmap|triangular|auto (default trie)")
        .flag("fuse-12", "fuse passes 1+2 via triangular matrix (ref [6])")
        .opt("fail-prob", "fault model: per-attempt failure probability")
        .opt("straggler-prob", "fault model: per-attempt straggler probability")
        .flag("speculation", "fault model: speculative backup attempts")
        .flag("streamed", "mine through the on-disk segment store (out-of-core)")
        .flag("follow", "tail a growing segment store: delta refresh per append")
        .opt("window", "sliding window: mine the last N store blocks")
        .opt("step", "window slide granularity in blocks (default 1)")
        .opt("poll-ms", "--follow poll interval in milliseconds (default 500)")
        .opt("follow-rounds", "stop --follow after N polls (default: until killed)")
        .opt("cache-dir", "segment-store cache directory")
        .flag("verbose", "debug logging + live phase events")
        .flag("rules", "derive association rules (conf >= 0.9) at the end")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    if p.bool("verbose") {
        logging::set_level(Level::Debug);
    }
    let streamed = p.bool("streamed");
    // Parse --algo first: a typo'd name must fail before any dataset work
    // (a streamed Quest dataset can cost minutes to generate).
    let algo_flag = p.get("algo").unwrap_or("opt-vfpc");
    let single_algo = if algo_flag == "all" {
        None
    } else {
        // Typed parse via FromStr: the error already names the input and
        // lists the valid spellings; only `all` is CLI-specific.
        Some(algo_flag.parse::<Algorithm>().map_err(|e| anyhow::anyhow!("{e} (or `all`)"))?)
    };
    // --backend parses just as early, for the same clean one-line error.
    let backend = match p.get("backend") {
        Some(s) => s.parse::<CountingBackend>()?,
        None => CountingBackend::default(),
    };
    let cluster = common_cluster(&p)?;
    let seed = RunOptions::default().seed;
    // Bind the dataset + cluster to a session once; split-size, cluster
    // and empty-dataset validation happens here as typed MiningErrors.
    // `--split-lines 0` is invalid on every path, including --streamed
    // (where a nonzero value is merely overridden by the block size).
    if p.usize("split-lines")?.is_some_and(|s| s == 0) {
        return Err(MiningError::InvalidSplitLines.into());
    }
    let gen_mode = match p.get("gen-mode").unwrap_or("per-record") {
        "per-task" => GenMode::PerTask,
        "per-record" => GenMode::PerRecord,
        other => bail!("unknown --gen-mode {other:?}; expected per-record or per-task"),
    };
    let fault_model = fault_model_from_flags(&p)?;
    // Validate the user-provided query tunables before dataset work too:
    // the defaults are always valid, so a probe request carrying exactly
    // the explicit flag values checks everything the user typed.
    {
        let mut probe = MiningRequest::new(single_algo.unwrap_or(Algorithm::Spc));
        if let Some(ms) = p.f64("min-sup")? {
            probe = probe.min_sup(ms);
        }
        if let Some(n) = p.usize("fpc-n")? {
            probe = probe.fpc_n(n);
        }
        if let Some(alpha) = p.f64("dpc-alpha")? {
            probe = probe.dpc_alpha(alpha);
        }
        if let Some(beta) = p.f64("dpc-beta")? {
            probe = probe.dpc_beta(beta);
        }
        if let Some(model) = &fault_model {
            probe = probe.faults(model.clone());
        }
        probe.validate()?;
    }
    if p.bool("follow") || p.usize("window")?.is_some() {
        let Some(algo) = single_algo else {
            bail!("--follow/--window need a single algorithm; pick one with --algo");
        };
        if p.bool("rules") {
            bail!("--rules is not supported with --follow/--window");
        }
        return mine_live(&p, cluster, gen_mode, backend, fault_model, algo);
    }
    if p.usize("step")?.is_some() {
        bail!("--step needs --window");
    }
    let session = if streamed {
        let file = streamed_file(p.required("dataset")?, &cache_dir(&p), &cluster, seed)?;
        // Streamed runs split at the store's block granularity (the
        // builder's default for pre-stored files): finer splits would
        // re-decode a whole block file per overlapping map task.
        if p.usize("split-lines")?.is_some_and(|s| s != file.block_lines) {
            eprintln!(
                "note: --split-lines ignored for --streamed; using the store's block size ({})",
                file.block_lines
            );
        }
        MiningSession::builder(file, cluster.clone()).build()?
    } else {
        let db = load_db(&p)?;
        let mut builder = MiningSession::for_db(&db, cluster.clone()).seed(seed);
        if let Some(split) = p.usize("split-lines")? {
            builder = builder.split_lines(split);
        }
        builder.build()?
    };
    let name = session.file().name.clone();
    let min_sup = p
        .f64("min-sup")?
        .or_else(|| registry::reference_min_sup(&name))
        .unwrap_or(0.25);
    let request_for = |algo: Algorithm| -> Result<MiningRequest> {
        let mut req = MiningRequest::new(algo)
            .min_sup(min_sup)
            .gen_mode(gen_mode)
            .backend(backend)
            .dpc_alpha(match p.f64("dpc-alpha")? {
                Some(alpha) => alpha,
                None => registry::paper_dpc_alpha(&name),
            })
            .fuse_pass_2(p.bool("fuse-12"));
        if let Some(n) = p.usize("fpc-n")? {
            req = req.fpc_n(n);
        }
        if let Some(beta) = p.f64("dpc-beta")? {
            req = req.dpc_beta(beta);
        }
        if let Some(model) = &fault_model {
            req = req.faults(model.clone());
        }
        Ok(req)
    };

    if single_algo.is_none() {
        if p.bool("rules") {
            bail!("--rules needs a single algorithm; drop it or pick one with --algo");
        }
        // All seven algorithms over ONE session: Job1 runs once for the
        // shared support, every later query is served from the cache.
        let mut outcomes = Vec::with_capacity(Algorithm::ALL.len());
        println!(
            "all algorithms on {} @ min_sup {:.2}{}",
            name,
            min_sup,
            if streamed { " [streamed]" } else { "" }
        );
        let faulted_col = if fault_model.is_some() { " faulted(s)" } else { "" };
        println!(
            "{:<18} {:>7} {:>11} {:>10} {:>10}{faulted_col} {:>9}",
            "algorithm", "phases", "candidates", "total(s)", "actual(s)", "frequent"
        );
        for algo in Algorithm::ALL {
            let req = request_for(algo)?;
            let out = run_with_live_events(&session, &req, p.bool("verbose"), Some(algo.name()))?;
            let faulted_cell = match out.faulted_actual_time() {
                Some(t) => format!(" {t:>10.0}"),
                None => String::new(),
            };
            println!(
                "{:<18} {:>7} {:>11} {:>10.0} {:>10.0}{faulted_cell} {:>9}",
                algo.name(),
                out.n_phases(),
                out.phases.iter().map(|ph| ph.candidates).sum::<u64>(),
                out.total_time,
                out.actual_time,
                out.total_frequent()
            );
            outcomes.push(out);
        }
        let refs: Vec<&MiningOutcome> = outcomes.iter().collect();
        println!();
        if fault_model.is_some() {
            // The fault view: every phase's clean→faulted makespan plus the
            // run's injection counters.
            println!(
                "{}",
                tables::fault_phase_table(
                    &refs,
                    &format!(
                        "{name} @ min_sup {min_sup}: per-phase makespan, clean→faulted (s)"
                    )
                )
            );
        } else {
            println!(
                "{}",
                tables::phase_time_table(
                    &refs,
                    &format!("{name} @ min_sup {min_sup}: per-phase elapsed time (s)")
                )
            );
        }
        let st = session.stats();
        println!(
            "session: {} queries served; Job1 executed {} time(s), {} served from cache",
            st.queries, st.job1_runs, st.job1_cache_hits
        );
        return Ok(());
    }

    // lint:allow(unwrap-in-library): the `--algo all` branch returned above,
    // so a single algorithm is the only way to reach this line.
    let algo = single_algo.expect("the --algo all branch returned above");
    let req = request_for(algo)?;
    let out = run_with_live_events(&session, &req, p.bool("verbose"), None)?;
    println!(
        "{} on {} @ min_sup {:.2} (min_count {}){}",
        algo.name(),
        name,
        min_sup,
        out.min_count,
        if streamed { " [streamed]" } else { "" }
    );
    // Header fault columns use the same widths as the data rows' cells.
    let faulted_col = if out.fault_model.is_some() {
        format!(" {:>10} {:>26}", "faulted(s)", "attempts/fail/strag/spec")
    } else {
        String::new()
    };
    println!(
        "{:>5} {:>6} {:>7} {:>11} {:>10} {:>12} {:>10}{faulted_col}  {}",
        "phase", "passes", "k-range", "candidates", "backend", "elapsed(s)", "wall(s)", "job"
    );
    for ph in &out.phases {
        let k_range = if ph.n_passes <= 1 {
            format!("{}", ph.first_pass)
        } else {
            format!("{}-{}", ph.first_pass, ph.first_pass + ph.n_passes - 1)
        };
        let fault_cells = match &ph.faults {
            None => String::new(),
            Some(f) => {
                let t = f.totals();
                format!(
                    " {:>10.1} {:>26}",
                    f.elapsed(),
                    format!(
                        "{}/{}/{}/{}+{}",
                        t.attempts, t.failures, t.stragglers, t.speculative_launches,
                        t.speculative_wins
                    )
                )
            }
        };
        println!(
            "{:>5} {:>6} {:>7} {:>11} {:>10} {:>12.1} {:>10.3}{fault_cells}  {}",
            ph.phase,
            ph.n_passes,
            k_range,
            ph.candidates,
            ph.backend_label(),
            ph.elapsed,
            ph.wall,
            ph.job
        );
    }
    println!(
        "total {:.1} s simulated, actual {:.1} s, wall {:.3} s host",
        out.total_time, out.actual_time, out.wall_time
    );
    if let (Some(faulted_total), Some(faulted_actual), Some(t)) =
        (out.faulted_total_time(), out.faulted_actual_time(), out.fault_totals())
    {
        println!(
            "faulted total {:.1} s ({:+.1}%), actual {:.1} s — {} attempts, {} failures, \
             {} stragglers, {}/{} speculative launches/wins{}",
            faulted_total,
            100.0 * (faulted_total / out.total_time - 1.0),
            faulted_actual,
            t.attempts,
            t.failures,
            t.stragglers,
            t.speculative_launches,
            t.speculative_wins,
            if t.job_failed { " [some simulated phase EXHAUSTED its retries]" } else { "" }
        );
    }
    println!("frequent itemsets: {} across {} levels", out.total_frequent(), out.levels.len());
    println!("|L_k| profile: {:?}", out.lk_profile());
    if p.bool("verbose") {
        let mut total = mrapriori::mapreduce::Counters::new();
        for ph in &out.phases {
            total.merge(&ph.counters);
        }
        println!("aggregate counters: {total}");
        let w = cluster.weights;
        use mrapriori::mapreduce::keys as K;
        println!(
            "compute split (s): join={:.0} prune={:.0} cand={:.0} visit={:.0} bitmap={:.0} \
             triangle={:.0} tuples={:.0}",
            w.join_pair * total.get(K::JOIN_PAIRS) as f64,
            w.prune_check * total.get(K::PRUNE_CHECKS) as f64,
            w.cand_built * total.get(K::CANDS_BUILT) as f64,
            w.subset_visit * total.get(K::SUBSET_VISITS) as f64,
            w.bitmap_word * total.get(K::BITMAP_WORD_OPS) as f64,
            w.triangle_update * total.get(K::TRIANGLE_UPDATES) as f64,
            w.map_tuple * total.get(K::MAP_OUTPUT_TUPLES) as f64,
        );
    }

    if p.bool("rules") {
        let mined = mrapriori::apriori::sequential::MineResult {
            levels: out.levels.clone(),
            min_count: out.min_count,
            candidates_per_pass: vec![],
            gen_stats: Default::default(),
            subset_visits: 0,
        };
        let rules = mrapriori::apriori::rules::derive_rules(&mined, session.file().len(), 0.9);
        println!("\ntop association rules (conf >= 0.9):");
        for r in rules.iter().take(15) {
            println!("  {r}");
        }
    }
    Ok(())
}

/// Resolve a dataset name into the directory of its segment store — the
/// follow/window entry point. Mirrors [`streamed_file`]'s resolution order
/// (store directory first: the natural `--follow` target is a store some
/// other process appends to), but hands back the directory so a
/// [`FollowSession`](mrapriori::coordinator::FollowSession) can reopen it
/// per refresh.
fn store_dir(name: &str, cache: &Path) -> Result<PathBuf> {
    use anyhow::Context as _;
    use mrapriori::hdfs::segment;
    let as_path = Path::new(name);
    if segment::exists(as_path) {
        return Ok(as_path.to_path_buf());
    }
    if registry::quest_params(name).is_some() {
        let src = registry::quest_store(name, cache)
            .with_context(|| format!("building quest store for {name:?}"))?;
        return Ok(src.dir().to_path_buf());
    }
    if let Some(db) = registry::try_load(name) {
        let dir = cache.join(&db.name);
        if segment::exists(&dir) {
            let src = segment::open(&dir)?;
            if src.len() >= db.len() {
                // `>=`: a followed store legitimately outgrows the
                // registry dataset it was seeded from.
                return Ok(dir);
            }
        }
        segment::write_store(
            &dir,
            db.name.as_str(),
            registry::split_lines(&db.name),
            db.n_items,
            db.txns.iter().cloned(),
        )
        .with_context(|| format!("writing store for {name:?}"))?;
        return Ok(dir);
    }
    if as_path.exists() {
        let stem = as_path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset");
        let (dir, fp_path, fingerprint) = import_cache_entry(cache, as_path);
        let fresh = !fingerprint.is_empty()
            && std::fs::read_to_string(&fp_path).is_ok_and(|s| s == fingerprint);
        if segment::exists(&dir) && fresh {
            return Ok(dir);
        }
        loader::import_segmented(as_path, &dir, registry::split_lines(stem))
            .with_context(|| format!("importing {name:?} into {dir:?}"))?;
        std::fs::write(&fp_path, &fingerprint)?;
        return Ok(dir);
    }
    Err(unknown_dataset(name))
}

/// One line per refresh: revision, path taken (delta vs full), coverage,
/// and the symmetric difference against the previous refresh.
fn print_refresh(out: &mrapriori::coordinator::DeltaOutcome, rev: usize) {
    println!(
        "rev {rev} [{}] records {}..{}: {} frequent (+{} -{} ={}), rescanned {}/{} blocks",
        if out.delta { "delta" } else { "full" },
        out.coverage.start,
        out.coverage.end,
        out.total_frequent(),
        out.added.len(),
        out.removed.len(),
        out.retained,
        out.blocks_rescanned,
        out.total_blocks
    );
}

/// `mine --follow` / `mine --window N [--step S]`: live queries over a
/// growing segment store through the incremental subsystem (DESIGN.md §13).
/// `--window` without `--follow` answers once and exits; `--follow` polls
/// the store and prints one line per refresh that found changes.
fn mine_live(
    p: &mrapriori::util::flags::Parsed,
    cluster: ClusterConfig,
    gen_mode: GenMode,
    backend: CountingBackend,
    fault_model: Option<FaultModel>,
    algo: Algorithm,
) -> Result<()> {
    use mrapriori::coordinator::{FollowSession, WindowSpec};
    let dir = store_dir(p.required("dataset")?, &cache_dir(p))?;
    let mut follow = FollowSession::open(&dir, cluster)?;
    let ds = follow.session().file().name.clone();
    let min_sup = p.f64("min-sup")?.or_else(|| registry::reference_min_sup(&ds)).unwrap_or(0.25);
    let mut req = MiningRequest::new(algo)
        .min_sup(min_sup)
        .gen_mode(gen_mode)
        .backend(backend)
        .dpc_alpha(match p.f64("dpc-alpha")? {
            Some(alpha) => alpha,
            None => registry::paper_dpc_alpha(&ds),
        })
        .fuse_pass_2(p.bool("fuse-12"));
    if let Some(n) = p.usize("fpc-n")? {
        req = req.fpc_n(n);
    }
    if let Some(beta) = p.f64("dpc-beta")? {
        req = req.dpc_beta(beta);
    }
    if let Some(model) = &fault_model {
        req = req.faults(model.clone());
    }
    let window = match p.usize("window")? {
        Some(blocks) => {
            let spec = WindowSpec::new(blocks).step(p.usize("step")?.unwrap_or(1));
            spec.validate()?;
            Some(spec)
        }
        None => {
            if p.usize("step")?.is_some() {
                bail!("--step needs --window");
            }
            None
        }
    };

    if let (false, Some(spec)) = (p.bool("follow"), window) {
        // One-shot window query over the store as it stands.
        let out = follow.refresh_window(&req, spec)?;
        println!(
            "{} on {} @ min_sup {:.2} (min_count {}), window {} blocks step {}",
            algo.name(),
            ds,
            min_sup,
            out.min_count,
            spec.blocks,
            spec.step
        );
        print_refresh(&out, follow.rev());
        println!("|L_k| profile: {:?}", out.lk_profile());
        return Ok(());
    }

    let poll = std::time::Duration::from_millis(p.usize("poll-ms")?.unwrap_or(500) as u64);
    let rounds = p.usize("follow-rounds")?;
    println!(
        "following {} (rev {}) @ min_sup {:.2} with {}{}",
        dir.display(),
        follow.rev(),
        min_sup,
        algo.name(),
        match &window {
            Some(s) => format!(", window {} blocks step {}", s.blocks, s.step),
            None => String::new(),
        }
    );
    let mut round = 0usize;
    loop {
        match window {
            Some(spec) => {
                let out = follow.refresh_window(&req, spec)?;
                // Window refreshes always answer; only narrate movement
                // (the bootstrap round included — everything is "added").
                if out.changed() || round == 0 {
                    print_refresh(&out, follow.rev());
                }
            }
            None => {
                if let Some(out) = follow.refresh(&req)? {
                    print_refresh(&out, follow.rev());
                }
            }
        }
        round += 1;
        if rounds.is_some_and(|r| round >= r) {
            break;
        }
        std::thread::sleep(poll);
    }
    let st = follow.stats();
    println!(
        "follow: {} refreshes, {} blocks rescanned, {} full fallbacks",
        st.delta_runs, st.blocks_rescanned, st.full_fallbacks
    );
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let set = FlagSet::new("inspect", "dataset summary statistics")
        .opt("dataset", "registry name or file path")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    let db = load_db(&p)?;
    println!("{}", stats::summarize(&db));
    Ok(())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let set = FlagSet::new("generate", "write a dataset to a FIMI file or segment store")
        .opt("dataset", "registry or Quest-family name (or FIMI file path)")
        .opt("out", "output path (a directory with --segmented)")
        .opt("scale", "repeat to N transactions (e.g. 200000 for c20d200k)")
        .flag("segmented", "write an on-disk segment store instead of one text file")
        .flag("append", "with --segmented: append to the existing store at --out")
        .opt("take", "with --append: append only the first N records")
        .opt("block-lines", "records per segment block (default: the dataset's split size)")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    let name = p.required("dataset")?;
    let out = p.required("out")?;
    let quest = registry::quest_params(name);

    if p.bool("segmented") {
        use mrapriori::hdfs::segment;
        if p.usize("scale")?.is_some() {
            bail!("--scale is not supported with --segmented (pick a larger t*i*d* name)");
        }
        let block = p.usize("block-lines")?.unwrap_or_else(|| registry::split_lines(name));
        if block == 0 {
            bail!("--block-lines must be > 0");
        }
        if p.bool("append") {
            // Grow an existing store in place — the writer republishes the
            // manifest atomically, so concurrent followers only ever see
            // complete revisions. Shape mismatches come back as typed
            // `SegmentError::AppendMismatch`.
            let existing = segment::open(Path::new(out))?;
            let block = p.usize("block-lines")?.unwrap_or_else(|| existing.block_lines());
            let take = p.usize("take")?.unwrap_or(usize::MAX);
            let before = existing.len();
            let (n_items, txns): (usize, Box<dyn Iterator<Item = mrapriori::itemset::Itemset>>) =
                if let Some(qp) = &quest {
                    (qp.n_items, Box::new(QuestGen::new(qp)))
                } else if let Some(db) = registry::try_load(name) {
                    (db.n_items, Box::new(db.txns.into_iter()))
                } else if Path::new(name).exists() {
                    let db = loader::load_file(Path::new(name))?;
                    (db.n_items, Box::new(db.txns.into_iter()))
                } else {
                    return Err(unknown_dataset(name));
                };
            let mut w = segment::SegmentWriter::append(out, n_items, block)?;
            let mut appended = 0usize;
            for t in txns.take(take) {
                w.push(&t)?;
                appended += 1;
            }
            let src = w.finish()?;
            println!(
                "appended {appended} transactions ({before} -> {}) in {} blocks at {out} \
                 (segment store)",
                src.len(),
                src.len().div_ceil(src.block_lines())
            );
            return Ok(());
        }
        let src = if let Some(qp) = &quest {
            // Quest names stream straight to disk — never materialized.
            segment::write_store(
                out,
                name.to_ascii_lowercase(),
                block,
                qp.n_items,
                QuestGen::new(qp),
            )?
        } else if let Some(db) = registry::try_load(name) {
            // Registry before file path, like every other resolution site.
            segment::write_store(out, db.name.as_str(), block, db.n_items, db.txns.iter().cloned())?
        } else if Path::new(name).exists() {
            // FIMI files stream line by line through the importer.
            loader::import_segmented(Path::new(name), Path::new(out), block)?
        } else {
            return Err(unknown_dataset(name));
        };
        let blocks = src.len().div_ceil(src.block_lines());
        println!("wrote {} transactions in {blocks} blocks to {out} (segment store)", src.len());
        return Ok(());
    }

    if let (Some(qp), None) = (&quest, p.usize("scale")?) {
        // Quest names stream to the text file record by record.
        let n = loader::write_file_streamed(QuestGen::new(qp), Path::new(out))?;
        println!("wrote {n} transactions to {out}");
        return Ok(());
    }

    let mut db = resolve_db(name)?;
    if let Some(target) = p.usize("scale")? {
        let scaled_name = format!("{}-x{}", db.name, target);
        db = db.scaled_to(target, scaled_name);
    }
    loader::write_file(&db, Path::new(out))?;
    println!("wrote {} transactions to {}", db.len(), out);
    Ok(())
}

fn cmd_lk(args: &[String]) -> Result<()> {
    let set = FlagSet::new("lk", "|L_k| per pass via the sequential oracle (Table 6)")
        .opt("dataset", "registry name or file path")
        .opt("min-sup", "fractional minimum support")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    let db = load_db(&p)?;
    let min_sup = p
        .f64("min-sup")?
        .or_else(|| registry::reference_min_sup(&db.name))
        .unwrap_or(0.25);
    let r = mrapriori::apriori::sequential::mine(&db, min_sup);
    println!("{} @ min_sup {:.2}: |L_k| = {:?}", db.name, min_sup, r.lk_profile());
    println!("total {} frequent itemsets, max length {}", r.total_frequent(), r.max_len());
    Ok(())
}

/// `serve`: run the TCP mining daemon until a client sends `SHUTDOWN` (or
/// the process is killed). Binds before printing so the `serving on`
/// line — which CI and the tests poll for — always carries a live
/// address, then blocks in [`Server::wait`] draining admitted queries.
fn cmd_serve(args: &[String]) -> Result<()> {
    use mrapriori::serve::{ServeConfig, Server};
    let set = FlagSet::new("serve", "TCP mining daemon over the session API (DESIGN.md §12)")
        .opt("host", "interface to bind (default 127.0.0.1)")
        .opt("port", "TCP port; 0 picks an ephemeral one (default 0)")
        .opt("max-sessions", "open dataset sessions before LRU eviction (default 3)")
        .opt("max-pending", "admission bound on queued queries (default 64)")
        .opt("quota", "per-connection in-flight query limit (default 4)")
        .opt("result-cache", "full responses cached; 0 disables (default 32)")
        .opt("query-threads", "concurrent query executions (default 2)")
        .flag("no-coalesce", "run identical concurrent queries separately")
        .opt("workers", "host threads for the one shared executor pool")
        .opt("cluster-config", "TOML cluster config path")
        .opt("data-nodes", "uniform cluster of N DataNodes")
        .flag("verbose", "debug logging")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    if p.bool("verbose") {
        logging::set_level(Level::Debug);
    }
    let mut config = ServeConfig::new(common_cluster(&p)?);
    if let Some(host) = p.get("host") {
        config.host = host.to_string();
    }
    if let Some(port) = p.usize("port")? {
        config.port = u16::try_from(port).map_err(|_| anyhow::anyhow!("--port out of range"))?;
    }
    if let Some(n) = p.usize("max-sessions")? {
        config.max_sessions = n;
    }
    if let Some(n) = p.usize("max-pending")? {
        config.max_pending = n;
    }
    if let Some(n) = p.usize("quota")? {
        config.client_quota = n;
    }
    if let Some(n) = p.usize("result-cache")? {
        config.result_cache = n;
    }
    if let Some(n) = p.usize("query-threads")? {
        config.query_threads = n;
    }
    config.coalesce = !p.bool("no-coalesce");
    let server = Server::start(config)?;
    // Flush explicitly: under a pipe stdout is block-buffered, and the CI
    // smoke step greps this line to learn the ephemeral port.
    println!("serving on {}", server.addr());
    use std::io::Write as _;
    std::io::stdout().flush()?;
    server.wait();
    println!("serve: drained and shut down");
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let set = FlagSet::new("sweep", "figure sweep on one dataset, a scale grid, or a fault grid")
        .opt("dataset", "registry name or file path (figure-sweep / fault-grid mode)")
        .opt("min-sups", "comma-separated min_sup list (default: paper sweep)")
        .opt("datasets", "comma-separated names -> algorithm x dataset scale grid")
        .opt("algos", "grid algorithms, comma-separated (default: spc,opt-etdpc)")
        .opt("backend", "grid counting backend: trie|bitmap|triangular|auto (default trie)")
        .opt("min-sup", "single min_sup for every grid cell (default: per-dataset)")
        .flag("faults", "clean-vs-faulted robustness grid for all seven algorithms")
        .opt("fail-prob", "fault grid: failure probability (default 0.05)")
        .opt("straggler-prob", "fault grid: straggler probability (default 0.15)")
        .flag("in-memory", "grid mode: materialize datasets instead of streaming")
        .opt("cache-dir", "segment-store cache directory")
        .opt("json-out", "grid mode: write the scale table as JSON here")
        .opt("md-out", "grid mode: write the markdown scale table here")
        .opt("workers", "host threads")
        .opt("cluster-config", "TOML cluster config path")
        .opt("data-nodes", "uniform cluster of N DataNodes")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    if p.has("datasets") {
        if p.bool("faults") {
            bail!("--faults runs on one dataset; use --dataset, not --datasets");
        }
        return scale_sweep(&p);
    }
    if p.bool("faults") {
        return fault_grid(&p);
    }
    let db = load_db(&p)?;
    let mut spec = SweepSpec::paper(&db);
    spec.cluster = common_cluster(&p)?;
    if let Some(sups) = p.f64_list("min-sups")? {
        spec.min_sups = sups;
    }
    let result = tables::sweep(&spec)?;
    println!("{}", tables::figure_a(&result, &db.name));
    println!("{}", tables::figure_b(&result, &db.name));
    Ok(())
}

/// `sweep --faults`: the clean-vs-faulted robustness grid — all seven
/// algorithms on one dataset and one session, each mined under the default
/// fault-scenario family (clean, failures, stragglers, stragglers +
/// speculation), rendered as markdown time + injection-counter tables.
/// Frequent-itemset output is identical in every cell (faults only move
/// simulated time), so the grid isolates scheduling robustness.
fn fault_grid(p: &mrapriori::util::flags::Parsed) -> Result<()> {
    let cluster = common_cluster(p)?;
    let db = load_db(p)?;
    let min_sup = p
        .f64("min-sup")?
        .or_else(|| registry::reference_min_sup(&db.name))
        .unwrap_or(0.25);
    let dpc_alpha = registry::paper_dpc_alpha(&db.name);
    let scenarios = FaultScenario::grid(
        p.f64("fail-prob")?.unwrap_or(0.05),
        p.f64("straggler-prob")?.unwrap_or(0.15),
    );
    for scenario in &scenarios {
        if let Some(model) = &scenario.model {
            model.validate().map_err(MiningError::InvalidFaultModel)?;
        }
    }
    let session = MiningSession::for_db(&db, cluster)
        .split_lines(registry::split_lines(&db.name))
        .build()?;
    let algos = Algorithm::ALL;
    let grid = tables::fault_sweep(&session, &algos, &scenarios, |algo| {
        MiningRequest::new(algo).min_sup(min_sup).dpc_alpha(dpc_alpha)
    })?;
    println!("fault robustness on {} @ min_sup {min_sup:.2} (actual s):\n", db.name);
    print!("{}", tables::fault_markdown(&algos, &scenarios, &grid));
    Ok(())
}

/// `sweep --datasets ...`: the Fig 5(a)-style algorithm x dataset scale
/// grid. Datasets stream through the segment store by default, so
/// T*I*D100K/1M-class entries mine with memory bounded by the block size;
/// results render as a markdown table (stdout / --md-out) and JSON
/// (--json-out).
fn scale_sweep(p: &mrapriori::util::flags::Parsed) -> Result<()> {
    let cluster = common_cluster(p)?;
    let cache = cache_dir(p);
    let names: Vec<&str> = p
        .get("datasets")
        .unwrap_or_default()
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        bail!("--datasets needs at least one name");
    }
    let algos: Vec<Algorithm> = match p.get("algos") {
        None => vec![Algorithm::Spc, Algorithm::OptimizedEtdpc],
        Some(list) => list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| s.parse::<Algorithm>().map_err(anyhow::Error::from))
            .collect::<Result<_>>()?,
    };
    let backend = match p.get("backend") {
        Some(s) => s.parse::<CountingBackend>()?,
        None => CountingBackend::default(),
    };
    let seed = RunOptions::default().seed;
    let mut runs = Vec::with_capacity(names.len());
    for name in names {
        let file = if p.bool("in-memory") {
            let db = resolve_db(name)?;
            let block = registry::split_lines(&db.name);
            hdfs::put(&db, block, cluster.nodes.len(), hdfs::DEFAULT_REPLICATION, seed)
        } else {
            streamed_file(name, &cache, &cluster, seed)?
        };
        let min_sup = match p.f64("min-sup")? {
            Some(ms) => ms,
            None => registry::reference_min_sup(&file.name).unwrap_or(0.01),
        };
        let dataset = file.name.clone();
        let n_txns = file.len();
        let split = registry::split_lines(&dataset);
        // One session per grid row: every algorithm after the first reuses
        // the row's Job1 scan.
        let session =
            MiningSession::builder(file, cluster.clone()).split_lines(split).build()?;
        let outcomes: Vec<MiningOutcome> = algos
            .iter()
            .map(|&algo| {
                eprintln!("  {} on {dataset} ({n_txns} txns) @ min_sup {min_sup}", algo.name());
                session.run(
                    &MiningRequest::new(algo)
                        .min_sup(min_sup)
                        .backend(backend)
                        .dpc_alpha(registry::paper_dpc_alpha(&dataset)),
                )
            })
            .collect::<Result<_, _>>()?;
        runs.push(ScaleRun { dataset, n_txns, min_sup, backend, outcomes });
    }
    let md = tables::scale_markdown(&algos, &runs);
    print!("{md}");
    if let Some(path) = p.get("md-out") {
        std::fs::write(path, &md)?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = p.get("json-out") {
        std::fs::write(path, tables::scale_json(&algos, &runs))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_calibrate(args: &[String]) -> Result<()> {
    let set = FlagSet::new("calibrate", "fit cost weights against the paper's Table 3")
        .flag("emit", "print the fitted config as TOML")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    let report = mrapriori::bench_harness::calibrate::run_calibration(p.bool("emit"));
    println!("{report}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The cache dir name is part of the on-disk contract: it must come
    /// out of the pinned SipHash-1-3, not whatever hasher std ships, or
    /// every toolchain bump would orphan the whole import cache. The
    /// fixture path does not exist, so `canonicalize` falls back to the
    /// path as given and the digest is reproducible anywhere.
    #[test]
    fn import_cache_dir_name_is_pinned() {
        let cache = Path::new("target/dataset-cache");
        let src = Path::new("pallas-lint-fixture/web_docs.dat");
        let (dir, fp, _fingerprint) = import_cache_entry(cache, src);
        assert_eq!(
            dir,
            Path::new("target/dataset-cache/import-web_docs-af1ea4c3e824dbd8")
        );
        assert_eq!(
            fp,
            Path::new("target/dataset-cache/import-web_docs-af1ea4c3e824dbd8.fingerprint")
        );
    }

    /// Same source path, different spellings that canonicalize apart must
    /// key different slots; the same spelling keys the same slot.
    #[test]
    fn import_cache_dir_is_deterministic_per_path() {
        let cache = Path::new("c");
        let a = import_cache_entry(cache, Path::new("x/one.dat"));
        let b = import_cache_entry(cache, Path::new("x/one.dat"));
        let c = import_cache_entry(cache, Path::new("y/one.dat"));
        assert_eq!(a.0, b.0);
        assert_ne!(a.0, c.0, "distinct paths must not collide on slot");
    }
}
