//! Bitmap (one-hot) encodings of itemsets and transactions for the XLA
//! counting backend.
//!
//! The L1 Pallas kernel computes containment as a tiled matmul:
//! `S = T · Cᵀ` over 0/1 f32 matrices; candidate `c` is contained in
//! transaction `t` iff `S[t, c] == |c|`. This module produces the padded
//! row-major f32 buffers the AOT-compiled executable expects.

use super::Item;

/// A fixed-shape tile of 0/1 rows, padded with zero rows/columns.
#[derive(Debug, Clone)]
pub struct BitmapTile {
    /// Row-major `rows x width` f32 0/1 matrix.
    pub data: Vec<f32>,
    /// Number of rows (padding included).
    pub rows: usize,
    /// Row width in items (bitmap columns).
    pub width: usize,
    /// Number of meaningful (non-padding) rows.
    pub valid_rows: usize,
}

impl BitmapTile {
    /// Encode up to `rows` itemsets (or transactions) over `width` items.
    /// Items >= `width` would corrupt the encoding, so they are rejected.
    pub fn encode(sets: &[&[Item]], rows: usize, width: usize) -> Result<Self, EncodeError> {
        if sets.len() > rows {
            return Err(EncodeError::TooManyRows { got: sets.len(), max: rows });
        }
        let mut data = vec![0f32; rows * width];
        for (r, set) in sets.iter().enumerate() {
            for &item in set.iter() {
                let i = item as usize;
                if i >= width {
                    return Err(EncodeError::ItemOutOfRange { item, width });
                }
                data[r * width + i] = 1.0;
            }
        }
        Ok(Self { data, rows, width, valid_rows: sets.len() })
    }

    /// Row lengths (|set| per row; 0 for padding rows). The kernel compares
    /// dot products against these. Padding rows get a sentinel length that
    /// can never be matched (width+1), so padded *candidates* never count.
    pub fn lengths_with_sentinel(sets: &[&[Item]], rows: usize, width: usize) -> Vec<f32> {
        let mut lens = vec![(width + 1) as f32; rows];
        for (r, set) in sets.iter().enumerate() {
            lens[r] = set.len() as f32;
        }
        lens
    }
}

#[derive(Debug, PartialEq)]
/// Why a tile could not be encoded.
pub enum EncodeError {
    /// More sets than tile rows.
    TooManyRows {
        /// Sets offered.
        got: usize,
        /// Tile row capacity.
        max: usize,
    },
    /// An item id does not fit the bitmap width.
    ItemOutOfRange {
        /// Offending item.
        item: Item,
        /// Bitmap width.
        width: usize,
    },
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EncodeError::TooManyRows { got, max } => {
                write!(f, "too many rows for tile: {got} > {max}")
            }
            EncodeError::ItemOutOfRange { item, width } => {
                write!(f, "item i{item} out of range for bitmap width {width}")
            }
        }
    }
}

impl std::error::Error for EncodeError {}

/// Dense u64-word bitset used by the *native* vectorized counting fallback
/// (and by tests as an oracle for the f32 encoding).
#[derive(Debug, Clone, PartialEq)]
pub struct BitVec64 {
    words: Vec<u64>,
    width: usize,
}

impl BitVec64 {
    /// Zeroed bitset of `width` bits.
    pub fn new(width: usize) -> Self {
        Self { words: vec![0u64; width.div_ceil(64)], width }
    }

    /// Bitset of `set` over `width` items.
    pub fn from_set(set: &[Item], width: usize) -> Self {
        let mut words = vec![0u64; width.div_ceil(64)];
        for &i in set {
            let i = i as usize;
            debug_assert!(i < width);
            words[i / 64] |= 1u64 << (i % 64);
        }
        Self { words, width }
    }

    /// Wrap a raw word buffer as a `width`-bit set. Short buffers are
    /// zero-padded to `width.div_ceil(64)` words; a longer buffer is a
    /// caller bug (its tail bits would be silently meaningless).
    pub fn from_words(mut words: Vec<u64>, width: usize) -> Self {
        let need = width.div_ceil(64);
        debug_assert!(words.len() <= need, "word buffer longer than width implies");
        words.resize(need, 0);
        Self { words, width }
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.width);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Bit width (valid bit indices are `0..width`).
    pub fn width(&self) -> usize {
        self.width
    }

    /// The underlying u64 words, least-significant bits first.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// True iff self ⊆ other.
    #[inline]
    pub fn is_subset_of(&self, other: &BitVec64) -> bool {
        debug_assert_eq!(self.width, other.width);
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }

    /// Number of set bits.
    pub fn popcount(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Dot product as containment check helper: |self ∩ other|.
    pub fn intersect_count(&self, other: &BitVec64) -> u32 {
        self.words.iter().zip(&other.words).map(|(a, b)| (a & b).count_ones()).sum()
    }

    /// Popcount of the multi-way AND of `rows` (all the same width). An
    /// empty slice intersects nothing: 0. The vertical TID-bitmap backend
    /// uses this shape — one row per item of a candidate — via the
    /// word-range form below so it can cache-block across candidates.
    pub fn intersect_count_many(rows: &[&BitVec64]) -> u64 {
        let Some(first) = rows.first() else { return 0 };
        Self::intersect_count_words(rows, 0, first.words.len())
    }

    /// Popcount of the multi-way AND of `rows` restricted to the word range
    /// `lo..hi` — the cache-blocked inner kernel: callers sweep one block
    /// of words across all candidates before moving to the next block, so
    /// every TID-list row is streamed through cache once per block.
    pub fn intersect_count_words(rows: &[&BitVec64], lo: usize, hi: usize) -> u64 {
        let Some((first, rest)) = rows.split_first() else { return 0 };
        debug_assert!(rows.iter().all(|r| r.width == first.width));
        let mut count = 0u64;
        for w in lo..hi {
            let mut acc = first.words[w];
            for r in rest {
                acc &= r.words[w];
            }
            count += u64::from(acc.count_ones());
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{forall, Gen, ItemsetGen};

    #[test]
    fn encode_basic() {
        let sets: Vec<&[Item]> = vec![&[0, 2], &[1]];
        let t = BitmapTile::encode(&sets, 4, 4).unwrap();
        assert_eq!(t.valid_rows, 2);
        assert_eq!(&t.data[0..4], &[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(&t.data[4..8], &[0.0, 1.0, 0.0, 0.0]);
        assert_eq!(&t.data[8..16], &[0.0; 8]); // padding rows all zero
    }

    #[test]
    fn encode_rejects_overflow() {
        let sets: Vec<&[Item]> = vec![&[5]];
        assert_eq!(
            BitmapTile::encode(&sets, 2, 4).unwrap_err(),
            EncodeError::ItemOutOfRange { item: 5, width: 4 }
        );
        let many: Vec<&[Item]> = vec![&[0], &[1], &[2]];
        assert!(matches!(
            BitmapTile::encode(&many, 2, 4),
            Err(EncodeError::TooManyRows { got: 3, max: 2 })
        ));
    }

    #[test]
    fn sentinel_lengths() {
        let sets: Vec<&[Item]> = vec![&[0, 1, 2]];
        let lens = BitmapTile::lengths_with_sentinel(&sets, 3, 8);
        assert_eq!(lens, vec![3.0, 9.0, 9.0]);
    }

    #[test]
    fn bitvec_subset_and_counts() {
        let a = BitVec64::from_set(&[1, 3], 128);
        let b = BitVec64::from_set(&[1, 2, 3, 100], 128);
        assert!(a.is_subset_of(&b));
        assert!(!b.is_subset_of(&a));
        assert_eq!(a.popcount(), 2);
        assert_eq!(a.intersect_count(&b), 2);
    }

    #[test]
    fn bitvec_width_not_multiple_of_64() {
        // width 70: the last word holds only 6 meaningful bits.
        let a = BitVec64::from_set(&[0, 63, 64, 69], 70);
        assert_eq!(a.popcount(), 4);
        assert_eq!(a.width(), 70);
        assert_eq!(a.words().len(), 2);
        let b = BitVec64::from_set(&[63, 69], 70);
        assert!(b.is_subset_of(&a));
        assert!(!a.is_subset_of(&b));
        assert_eq!(a.intersect_count(&b), 2);
        // width 1 and width 64 boundaries.
        assert_eq!(BitVec64::from_set(&[0], 1).popcount(), 1);
        assert_eq!(BitVec64::from_set(&[63], 64).words().len(), 1);
    }

    #[test]
    fn bitvec_empty_set_cases() {
        let empty = BitVec64::new(100);
        let full = BitVec64::from_set(&[0, 50, 99], 100);
        assert_eq!(empty.popcount(), 0);
        assert!(empty.is_subset_of(&full)); // ∅ ⊆ anything
        assert!(empty.is_subset_of(&empty));
        assert!(!full.is_subset_of(&empty));
        assert_eq!(empty.intersect_count(&full), 0);
        // Zero-width bitsets are degenerate but must not panic.
        let zero = BitVec64::new(0);
        assert_eq!(zero.popcount(), 0);
        assert!(zero.is_subset_of(&BitVec64::new(0)));
    }

    #[test]
    fn bitvec_chunk_boundary_bits() {
        // Bits straddling every word boundary of a 3-word set.
        let bits = [63usize, 64, 127, 128];
        let mut v = BitVec64::new(130);
        for &b in &bits {
            v.set(b);
        }
        assert_eq!(v.popcount(), 4);
        assert_eq!(v, BitVec64::from_set(&[63, 64, 127, 128], 130));
        // Intersections restricted to single-word chunks see only the bits
        // of that word: [63] | [64, 127] | [128].
        let rows = [&v, &v];
        assert_eq!(BitVec64::intersect_count_words(&rows, 0, 1), 1);
        assert_eq!(BitVec64::intersect_count_words(&rows, 1, 2), 2);
        assert_eq!(BitVec64::intersect_count_words(&rows, 2, 3), 1);
        assert_eq!(BitVec64::intersect_count_many(&rows), 4);
    }

    #[test]
    fn bitvec_intersect_many_matches_pairwise() {
        let a = BitVec64::from_set(&[1, 5, 64, 65, 127], 128);
        let b = BitVec64::from_set(&[1, 5, 65, 100], 128);
        let c = BitVec64::from_set(&[5, 65, 127], 128);
        // 3-way AND = {5, 65}.
        assert_eq!(BitVec64::intersect_count_many(&[&a, &b, &c]), 2);
        // Single row degenerates to popcount; empty slice to 0.
        assert_eq!(BitVec64::intersect_count_many(&[&a]), u64::from(a.popcount()));
        assert_eq!(BitVec64::intersect_count_many(&[]), 0);
    }

    #[test]
    fn bitvec_from_words_pads_short_buffers() {
        let v = BitVec64::from_words(vec![1u64 << 63], 130);
        assert_eq!(v.words().len(), 3);
        assert_eq!(v.popcount(), 1);
        let mut w = BitVec64::new(130);
        w.set(63);
        assert_eq!(v, w);
    }

    #[test]
    fn prop_bitvec_agrees_with_merge_subset() {
        let gen = ItemsetGen { universe: 100, max_len: 20 };
        forall(201, 200, &gen, |set| {
            let other_gen = ItemsetGen { universe: 100, max_len: 20 };
            let mut rng = crate::util::rng::Rng::new(set.iter().map(|&x| x as u64).sum::<u64>());
            let other = other_gen.generate(&mut rng);
            let a = BitVec64::from_set(set, 100);
            let b = BitVec64::from_set(&other, 100);
            a.is_subset_of(&b) == crate::itemset::is_subset(set, &other)
                && (a.intersect_count(&b) == a.popcount()) == a.is_subset_of(&b)
        });
    }

    #[test]
    fn prop_dotproduct_containment_rule() {
        // The rule the XLA kernel relies on: dot(t, c) == |c| iff c ⊆ t.
        let gen = ItemsetGen { universe: 64, max_len: 16 };
        forall(202, 200, &gen, |cand| {
            let mut rng = crate::util::rng::Rng::new(7 + cand.len() as u64);
            let txn = ItemsetGen { universe: 64, max_len: 32 }.generate(&mut rng);
            let sets_c: Vec<&[Item]> = vec![cand];
            let sets_t: Vec<&[Item]> = vec![&txn];
            let c = BitmapTile::encode(&sets_c, 1, 64).unwrap();
            let t = BitmapTile::encode(&sets_t, 1, 64).unwrap();
            let dot: f32 = c.data.iter().zip(&t.data).map(|(a, b)| a * b).sum();
            (dot == cand.len() as f32) == crate::itemset::is_subset(cand, &txn)
        });
    }
}
