//! Hash-table trie: a prefix tree whose child edges are hash maps instead
//! of sorted vectors — the variant the paper's ref [16] (Singh et al.,
//! ICCCA'16) found to "drastically outperform trie and hash tree" for
//! MapReduce Apriori in Java.
//!
//! Interface-compatible with [`super::Trie`]; the data-structure ablation
//! bench replays [16]'s comparison on this implementation (in rust the
//! sorted-vec trie usually wins back — cache locality beats hashing for the
//! small child sets here; the bench reports whichever way it lands).

use super::{Item, Itemset};
use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
struct Node {
    children: HashMap<Item, u32>,
    count: u64,
}

/// Prefix tree with hash-map children over fixed-length itemsets.
#[derive(Debug, Clone)]
pub struct HashTableTrie {
    nodes: Vec<Node>,
    k: usize,
    len: usize,
}

const ROOT: u32 = 0;

impl HashTableTrie {
    /// Empty trie for k-itemsets.
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        Self { nodes: vec![Node::default()], k, len: 0 }
    }

    /// Bulk-build from canonical k-itemsets.
    pub fn from_itemsets<'a, I: IntoIterator<Item = &'a Itemset>>(k: usize, sets: I) -> Self {
        let mut t = Self::new(k);
        for s in sets {
            t.insert(s);
        }
        t
    }

    /// The stored itemset length k.
    pub fn level(&self) -> usize {
        self.k
    }
    /// Number of stored itemsets.
    pub fn len(&self) -> usize {
        self.len
    }
    /// Whether the trie stores nothing.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    /// Total allocated nodes.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Insert a canonical k-itemset; returns whether it was new.
    pub fn insert(&mut self, set: &[Item]) -> bool {
        debug_assert_eq!(set.len(), self.k);
        debug_assert!(super::is_canonical(set));
        let mut node = ROOT;
        let mut created = false;
        for &item in set {
            match self.nodes[node as usize].children.get(&item) {
                Some(&c) => node = c,
                None => {
                    let id = self.nodes.len() as u32;
                    self.nodes.push(Node::default());
                    self.nodes[node as usize].children.insert(item, id);
                    node = id;
                    created = true;
                }
            }
        }
        if created {
            self.len += 1;
        }
        created
    }

    /// Membership test for a canonical k-itemset.
    pub fn contains(&self, set: &[Item]) -> bool {
        let mut node = ROOT;
        for item in set {
            match self.nodes[node as usize].children.get(item) {
                Some(&c) => node = c,
                None => return false,
            }
        }
        true
    }

    /// Support count accumulated for `set` (0 if absent).
    pub fn count_of(&self, set: &[Item]) -> Option<u64> {
        let mut node = ROOT;
        for item in set {
            node = *self.nodes[node as usize].children.get(item)?;
        }
        Some(self.nodes[node as usize].count)
    }

    /// Subset counting: for each remaining transaction item, one hash probe
    /// per (node, item) pair — [16]'s key trade: O(1) probes instead of the
    /// sorted merge, at the cost of hashing and cache misses.
    /// Returns `(nodes visited, leaves hit)`.
    pub fn count_transaction(&mut self, txn: &[Item]) -> (u64, u64) {
        let mut visits = 0u64;
        let mut hits = 0u64;
        let mut stack: Vec<(u32, usize, usize)> = vec![(ROOT, 0, 0)];
        while let Some((node, start, depth)) = stack.pop() {
            if depth == self.k {
                self.nodes[node as usize].count += 1;
                hits += 1;
                continue;
            }
            // Remaining txn items each get one probe at this node.
            for (pos, item) in txn.iter().enumerate().skip(start) {
                if let Some(&c) = self.nodes[node as usize].children.get(item) {
                    visits += 1;
                    stack.push((c, pos + 1, depth + 1));
                }
            }
        }
        (visits, hits)
    }

    /// Reset all support counts to zero.
    pub fn clear_counts(&mut self) {
        for n in &mut self.nodes {
            n.count = 0;
        }
    }

    /// All stored `(itemset, count)` pairs, sorted.
    pub fn entries(&self) -> Vec<(Itemset, u64)> {
        let mut out = Vec::with_capacity(self.len);
        let mut prefix = Vec::with_capacity(self.k);
        self.collect(ROOT, &mut prefix, &mut out);
        out.sort();
        out
    }

    fn collect(&self, node: u32, prefix: &mut Itemset, out: &mut Vec<(Itemset, u64)>) {
        if prefix.len() == self.k {
            out.push((prefix.clone(), self.nodes[node as usize].count));
            return;
        }
        for (&item, &c) in &self.nodes[node as usize].children {
            prefix.push(item);
            self.collect(c, prefix, out);
            prefix.pop();
        }
    }

    /// Itemsets whose count reaches `min_count`, with counts, sorted.
    pub fn frequent(&self, min_count: u64) -> Vec<(Itemset, u64)> {
        self.entries().into_iter().filter(|(_, c)| *c >= min_count).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::itemset::Trie;
    use crate::util::check::{forall, DbGen};

    #[test]
    fn basics_match_trie_semantics() {
        let sets: Vec<Itemset> = vec![vec![1, 2], vec![1, 3], vec![2, 9]];
        let mut ht = HashTableTrie::from_itemsets(2, sets.iter());
        assert_eq!(ht.len(), 3);
        assert!(ht.contains(&[1, 3]));
        assert!(!ht.contains(&[3, 9]));
        assert!(!ht.insert(&[1, 2]));
        ht.count_transaction(&[1, 2, 3]);
        assert_eq!(ht.count_of(&[1, 2]), Some(1));
        assert_eq!(ht.count_of(&[2, 9]), Some(0));
        let e = ht.entries();
        assert_eq!(e[0].0, vec![1, 2]); // sorted
    }

    #[test]
    fn prop_counts_match_trie() {
        let gen = DbGen { universe: 15, max_txns: 20, max_width: 8 };
        forall(902, 60, &gen, |db| {
            let mut sets: Vec<Itemset> = Vec::new();
            for t in db.txns.iter().take(8) {
                if t.len() >= 3 {
                    sets.push(vec![t[0], t[1], t[2]]);
                    sets.push(vec![t[0], t[t.len() / 2].max(t[0] + 1), t[t.len() - 1]]);
                }
            }
            sets.retain(|s| crate::itemset::is_canonical(s) && s.len() == 3);
            sets.sort();
            sets.dedup();
            if sets.is_empty() {
                return true;
            }
            let mut ht = HashTableTrie::from_itemsets(3, sets.iter());
            let mut trie = Trie::from_itemsets(3, sets.iter());
            for t in &db.txns {
                ht.count_transaction(t);
                trie.count_transaction(t);
            }
            sets.iter().all(|s| ht.count_of(s) == trie.count_of(s))
                && ht.entries() == trie.iter().collect::<Vec<_>>()
        });
    }

    #[test]
    fn clear_and_frequent() {
        let sets: Vec<Itemset> = vec![vec![0, 1]];
        let mut ht = HashTableTrie::from_itemsets(2, sets.iter());
        ht.count_transaction(&[0, 1, 2]);
        ht.count_transaction(&[0, 1]);
        assert_eq!(ht.frequent(2), vec![(vec![0, 1], 2)]);
        ht.clear_counts();
        assert!(ht.frequent(1).is_empty());
    }
}
