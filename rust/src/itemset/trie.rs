//! Arena-backed prefix tree (trie) over itemsets, after Bodon & Rónyai —
//! the data structure the paper uses for `trieL_k` / `trieC_k` (§4).
//!
//! All itemsets stored in one trie have the same length `k` (its *level*),
//! which is what the Apriori passes need. The trie supports:
//!
//! * membership (`contains`) — used by the pruning step,
//! * per-leaf support counters — used by `subset()` counting,
//! * sibling self-join — the `join` step of `apriori-gen` (§4.2),
//! * iteration in lexicographic order.
//!
//! Operation metering: the hot methods return/accumulate visit counts so the
//! cluster cost model can convert *real executed work* into simulated time.

use super::{Item, Itemset};

const ROOT: u32 = 0;

#[derive(Debug, Clone)]
struct Node {
    /// `(item, child id)` pairs sorted by item. Edge items live inline in
    /// the parent so the merge walks stay on one cache line instead of
    /// chasing every child node just to read its item (§Perf log).
    children: Vec<(Item, u32)>,
}

/// Prefix tree over fixed-length itemsets.
#[derive(Debug, Clone)]
pub struct Trie {
    nodes: Vec<Node>,
    /// Length of every stored itemset.
    k: usize,
    /// Number of stored itemsets (= number of leaves at depth k).
    len: usize,
    /// Support counters, indexed by node id. Separate from `nodes` so the
    /// counting walk can borrow the topology immutably while updating
    /// counters (disjoint-field borrow).
    counts: Vec<u64>,
    /// Reusable DFS stack for [`count_transaction`] (perf: avoids one heap
    /// allocation per transaction on the mapper hot path — §Perf log).
    scratch: Vec<(u32, usize, usize)>,
}

impl Trie {
    /// Empty trie that will hold itemsets of length `k` (k >= 1).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "trie level must be >= 1");
        Self {
            nodes: vec![Node { children: Vec::new() }],
            k,
            len: 0,
            counts: vec![0],
            scratch: Vec::new(),
        }
    }

    /// Build from an iterator of canonical itemsets (all of length `k`).
    pub fn from_itemsets<'a, I: IntoIterator<Item = &'a Itemset>>(k: usize, sets: I) -> Self {
        let mut t = Trie::new(k);
        for s in sets {
            t.insert(s);
        }
        t
    }

    /// The level (stored itemset length).
    pub fn level(&self) -> usize {
        self.k
    }

    /// Number of stored itemsets.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the trie stores nothing.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total allocated trie nodes (root included) — the paper's
    /// "size of prefix tree" (|trieC_k|) cost-model proxy.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    fn find_child(&self, node: u32, item: Item) -> Option<u32> {
        let kids = &self.nodes[node as usize].children;
        // Hybrid scan: child lists are tiny near the leaves (linear scan is
        // branch-predictor friendly), wide at the root (binary search wins).
        if kids.len() <= 12 {
            kids.iter().find(|&&(i, _)| i == item).map(|&(_, c)| c)
        } else {
            kids.binary_search_by(|&(i, _)| i.cmp(&item)).ok().map(|i| kids[i].1)
        }
    }

    /// Insert a canonical itemset of length `k`. Returns true if new.
    pub fn insert(&mut self, set: &[Item]) -> bool {
        debug_assert_eq!(set.len(), self.k, "itemset length must equal trie level");
        debug_assert!(super::is_canonical(set));
        let mut node = ROOT;
        let mut created = false;
        for &item in set {
            match self.find_child(node, item) {
                Some(c) => node = c,
                None => {
                    let id = self.nodes.len() as u32;
                    self.nodes.push(Node { children: Vec::new() });
                    self.counts.push(0);
                    let kids = &mut self.nodes[node as usize].children;
                    let pos = kids.binary_search_by(|&(i, _)| i.cmp(&item)).unwrap_err();
                    kids.insert(pos, (item, id));
                    node = id;
                    created = true;
                }
            }
        }
        if created {
            self.len += 1;
        }
        created
    }

    /// Membership test.
    pub fn contains(&self, set: &[Item]) -> bool {
        debug_assert_eq!(set.len(), self.k);
        let mut node = ROOT;
        for &item in set {
            match self.find_child(node, item) {
                Some(c) => node = c,
                None => return false,
            }
        }
        true
    }

    /// Add `delta` to the support counter of `set` (must be present).
    pub fn add_count(&mut self, set: &[Item], delta: u64) -> bool {
        let mut node = ROOT;
        for &item in set {
            match self.find_child(node, item) {
                Some(c) => node = c,
                None => return false,
            }
        }
        self.counts[node as usize] += delta;
        true
    }

    /// Read a support counter.
    pub fn count_of(&self, set: &[Item]) -> Option<u64> {
        let mut node = ROOT;
        for &item in set {
            node = self.find_child(node, item)?;
        }
        Some(self.counts[node as usize])
    }

    /// Reset all support counters to zero.
    pub fn clear_counts(&mut self) {
        self.counts.fill(0);
    }

    /// Iterate stored itemsets in lexicographic order.
    pub fn iter(&self) -> TrieIter<'_> {
        self.iter_with_counts(&self.counts)
    }

    /// Iterate with an *external* counter buffer (see
    /// [`count_transaction_into`]).
    pub fn iter_with_counts<'a>(&'a self, counts: &'a [u64]) -> TrieIter<'a> {
        debug_assert!(counts.len() >= self.nodes.len());
        TrieIter { trie: self, counts, stack: vec![(ROOT, 0)], prefix: Vec::with_capacity(self.k) }
    }

    /// Collect all stored itemsets.
    pub fn itemsets(&self) -> Vec<Itemset> {
        self.iter().map(|(s, _)| s).collect()
    }

    /// Collect itemsets whose count is >= `min_count`.
    pub fn frequent(&self, min_count: u64) -> Vec<(Itemset, u64)> {
        self.iter().filter(|(_, c)| *c >= min_count).collect()
    }

    /// `subset(trieC_k, t)` of the paper: invoke `on_hit` for every stored
    /// itemset that is a subset of the (sorted) transaction `txn`.
    /// Returns the number of trie nodes visited (cost-model meter).
    pub fn for_each_contained(
        &self,
        txn: &[Item],
        mut on_hit: impl FnMut(&[Item]),
    ) -> u64 {
        let mut prefix = Vec::with_capacity(self.k);
        let mut visits = 0u64;
        self.walk_contained(ROOT, txn, 0, &mut prefix, &mut on_hit, &mut visits);
        visits
    }

    fn walk_contained(
        &self,
        node: u32,
        txn: &[Item],
        start: usize,
        prefix: &mut Vec<Item>,
        on_hit: &mut impl FnMut(&[Item]),
        visits: &mut u64,
    ) {
        if prefix.len() == self.k {
            on_hit(prefix);
            return;
        }
        let kids = &self.nodes[node as usize].children;
        if kids.is_empty() {
            return;
        }
        // Merge-walk transaction items against sorted children.
        let mut ti = start;
        let mut ki = 0;
        while ti < txn.len() && ki < kids.len() {
            let (citem, child) = kids[ki];
            match txn[ti].cmp(&citem) {
                std::cmp::Ordering::Less => ti += 1,
                std::cmp::Ordering::Greater => ki += 1,
                std::cmp::Ordering::Equal => {
                    *visits += 1;
                    prefix.push(citem);
                    self.walk_contained(child, txn, ti + 1, prefix, on_hit, visits);
                    prefix.pop();
                    ti += 1;
                    ki += 1;
                }
            }
        }
    }

    /// Like [`for_each_contained`] but increments leaf counters directly —
    /// the fused map+combine fast path. Returns `(nodes visited, leaves hit)`.
    pub fn count_transaction(&mut self, txn: &[Item]) -> (u64, u64) {
        let mut stack = std::mem::take(&mut self.scratch);
        let nodes = &self.nodes;
        let out = Self::count_into_inner(nodes, self.k, txn, &mut self.counts, &mut stack);
        self.scratch = stack;
        out
    }

    /// Count into an *external* counter buffer (len >= [`node_count`]),
    /// leaving the trie itself untouched. This is what lets one shared
    /// read-only candidate trie serve many map tasks concurrently (the
    /// distributed-cache pattern; §Perf log).
    pub fn count_transaction_into(
        &self,
        txn: &[Item],
        counts: &mut [u64],
        scratch: &mut Vec<(u32, usize, usize)>,
    ) -> (u64, u64) {
        debug_assert!(counts.len() >= self.nodes.len());
        Self::count_into_inner(&self.nodes, self.k, txn, counts, scratch)
    }

    fn count_into_inner(
        nodes: &[Node],
        k: usize,
        txn: &[Item],
        counts: &mut [u64],
        stack: &mut Vec<(u32, usize, usize)>,
    ) -> (u64, u64) {
        let mut visits = 0u64;
        let mut hits = 0u64;
        // Iterative DFS; stack entries: (node, txn position, depth). The
        // stack buffer is caller-provided (allocation-free hot path).
        stack.clear();
        stack.push((ROOT, 0, 0));
        while let Some((node, start, depth)) = stack.pop() {
            if depth == k {
                counts[node as usize] += 1;
                hits += 1;
                continue;
            }
            // Same merge walk as walk_contained, but pushing onto the stack.
            let kids = &nodes[node as usize].children;
            let mut ti = start;
            let mut ki = 0;
            while ti < txn.len() && ki < kids.len() {
                let (citem, child) = kids[ki];
                match txn[ti].cmp(&citem) {
                    std::cmp::Ordering::Less => ti += 1,
                    std::cmp::Ordering::Greater => ki += 1,
                    std::cmp::Ordering::Equal => {
                        visits += 1;
                        stack.push((child, ti + 1, depth + 1));
                        ti += 1;
                        ki += 1;
                    }
                }
            }
        }
        (visits, hits)
    }

    /// Sibling self-join (the `join` step of `apriori-gen`): for every node at
    /// depth `k-1` and every ordered pair of its children `(a, b)` with
    /// `a.item < b.item`, produce `prefix ∪ {a.item, b.item}` — a candidate
    /// of length `k+1`. Invokes `on_candidate` per joined set and returns the
    /// number of join pairs considered.
    pub fn self_join(&self, mut on_candidate: impl FnMut(&[Item])) -> u64 {
        let mut prefix = Vec::with_capacity(self.k + 1);
        let mut joins = 0u64;
        self.walk_join(ROOT, 0, &mut prefix, &mut on_candidate, &mut joins);
        joins
    }

    fn walk_join(
        &self,
        node: u32,
        depth: usize,
        prefix: &mut Vec<Item>,
        on_candidate: &mut impl FnMut(&[Item]),
        joins: &mut u64,
    ) {
        if depth == self.k - 1 {
            let kids = &self.nodes[node as usize].children;
            for i in 0..kids.len() {
                for j in (i + 1)..kids.len() {
                    *joins += 1;
                    prefix.push(kids[i].0);
                    prefix.push(kids[j].0);
                    on_candidate(prefix);
                    prefix.pop();
                    prefix.pop();
                }
            }
            return;
        }
        for &(citem, c) in &self.nodes[node as usize].children {
            prefix.push(citem);
            self.walk_join(c, depth + 1, prefix, on_candidate, joins);
            prefix.pop();
        }
    }

    /// Rough heap footprint in bytes (for VMEM/memory reporting).
    pub fn approx_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<Node>()
            + self.nodes.iter().map(|n| n.children.capacity() * 8).sum::<usize>()
            + self.counts.capacity() * 8
    }
}

/// Lexicographic iterator yielding `(itemset, count)`.
pub struct TrieIter<'a> {
    trie: &'a Trie,
    counts: &'a [u64],
    /// (node, next-child-index); prefix holds items along the current path.
    stack: Vec<(u32, usize)>,
    prefix: Vec<Item>,
}

impl<'a> Iterator for TrieIter<'a> {
    type Item = (Itemset, u64);

    fn next(&mut self) -> Option<(Itemset, u64)> {
        loop {
            let &(node, child_idx) = self.stack.last()?;
            let n = &self.trie.nodes[node as usize];
            if self.prefix.len() == self.trie.k {
                // At a leaf: yield, then pop.
                let out = (self.prefix.clone(), self.counts[node as usize]);
                self.stack.pop();
                self.prefix.pop();
                return Some(out);
            }
            if child_idx < n.children.len() {
                self.stack.last_mut().unwrap().1 += 1;
                let (citem, c) = n.children[child_idx];
                self.prefix.push(citem);
                self.stack.push((c, 0));
            } else {
                self.stack.pop();
                self.prefix.pop();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{forall, DbGen, ItemsetGen, VecGen};

    fn trie_of(k: usize, sets: &[&[Item]]) -> Trie {
        let owned: Vec<Itemset> = sets.iter().map(|s| s.to_vec()).collect();
        Trie::from_itemsets(k, owned.iter())
    }

    #[test]
    fn insert_and_contains() {
        let t = trie_of(2, &[&[1, 2], &[1, 3], &[2, 3]]);
        assert_eq!(t.len(), 3);
        assert!(t.contains(&[1, 2]));
        assert!(t.contains(&[2, 3]));
        assert!(!t.contains(&[1, 4]));
        assert!(!t.contains(&[3, 4]));
    }

    #[test]
    fn duplicate_insert_ignored() {
        let mut t = Trie::new(2);
        assert!(t.insert(&[1, 2]));
        assert!(!t.insert(&[1, 2]));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn iteration_lexicographic() {
        let t = trie_of(2, &[&[2, 3], &[1, 3], &[1, 2]]);
        let sets: Vec<_> = t.iter().map(|(s, _)| s).collect();
        assert_eq!(sets, vec![vec![1, 2], vec![1, 3], vec![2, 3]]);
    }

    #[test]
    fn counting_via_transactions() {
        let mut t = trie_of(2, &[&[1, 2], &[1, 3], &[2, 3]]);
        t.count_transaction(&[1, 2, 3]); // hits all three
        t.count_transaction(&[1, 2]); // hits {1,2}
        t.count_transaction(&[3]); // hits none
        assert_eq!(t.count_of(&[1, 2]), Some(2));
        assert_eq!(t.count_of(&[1, 3]), Some(1));
        assert_eq!(t.count_of(&[2, 3]), Some(1));
    }

    #[test]
    fn for_each_contained_matches_count_transaction() {
        let sets: &[&[Item]] = &[&[1, 2, 4], &[1, 3, 4], &[2, 3, 4], &[1, 2, 3]];
        let mut t = trie_of(3, sets);
        let txn = &[1, 2, 3, 4];
        let mut hits = Vec::new();
        t.for_each_contained(txn, |s| hits.push(s.to_vec()));
        assert_eq!(hits.len(), 4);
        t.count_transaction(txn);
        for (s, c) in t.iter() {
            assert_eq!(c, 1, "set {s:?}");
        }
    }

    #[test]
    fn self_join_level1() {
        // L1 = {1},{2},{3} -> joins: {1,2},{1,3},{2,3}
        let t = trie_of(1, &[&[1], &[2], &[3]]);
        let mut out = Vec::new();
        let joins = t.self_join(|s| out.push(s.to_vec()));
        assert_eq!(joins, 3);
        assert_eq!(out, vec![vec![1, 2], vec![1, 3], vec![2, 3]]);
    }

    #[test]
    fn self_join_level2() {
        // L2 = {1,2},{1,3},{2,3} -> join on shared prefix {1}: {1,2,3}; prefix {2}: none
        let t = trie_of(2, &[&[1, 2], &[1, 3], &[2, 3]]);
        let mut out = Vec::new();
        t.self_join(|s| out.push(s.to_vec()));
        assert_eq!(out, vec![vec![1, 2, 3]]);
    }

    #[test]
    fn clear_counts_resets() {
        let mut t = trie_of(1, &[&[1], &[2]]);
        t.count_transaction(&[1, 2]);
        assert_eq!(t.count_of(&[1]), Some(1));
        t.clear_counts();
        assert_eq!(t.count_of(&[1]), Some(0));
    }

    #[test]
    fn frequent_filters_by_count() {
        let mut t = trie_of(1, &[&[1], &[2], &[3]]);
        t.count_transaction(&[1, 2]);
        t.count_transaction(&[1]);
        let f = t.frequent(2);
        assert_eq!(f, vec![(vec![1], 2)]);
    }

    // --- property tests -------------------------------------------------

    #[test]
    fn prop_roundtrip_insert_iter() {
        let gen = VecGen { inner: ItemsetGen { universe: 30, max_len: 4 }, max_len: 40 };
        forall(101, 60, &gen, |sets| {
            let fixed: Vec<Itemset> =
                sets.iter().filter(|s| s.len() == 3).cloned().collect();
            let mut expect: Vec<Itemset> = fixed.clone();
            expect.sort();
            expect.dedup();
            let t = Trie::from_itemsets(3, fixed.iter());
            t.itemsets() == expect && t.len() == expect.len()
        });
    }

    #[test]
    fn prop_contained_agrees_with_is_subset() {
        let gen = DbGen { universe: 20, max_txns: 12, max_width: 8 };
        forall(102, 60, &gen, |db| {
            // Store all width-2 subsets of the first txn plus noise sets.
            let mut sets: Vec<Itemset> = Vec::new();
            for t in &db.txns {
                if t.len() >= 2 {
                    sets.push(vec![t[0], t[t.len() - 1]].to_vec());
                }
            }
            sets.retain(|s| s[0] < s[1]);
            sets.sort();
            sets.dedup();
            if sets.is_empty() {
                return true;
            }
            let trie = Trie::from_itemsets(2, sets.iter());
            db.txns.iter().all(|txn| {
                let mut hits = Vec::new();
                trie.for_each_contained(txn, |s| hits.push(s.to_vec()));
                let expect: Vec<Itemset> = sets
                    .iter()
                    .filter(|s| crate::itemset::is_subset(s, txn))
                    .cloned()
                    .collect();
                hits == expect
            })
        });
    }

    #[test]
    fn prop_self_join_is_prefix_join() {
        // Candidates from self_join must equal the classic definition:
        // {a ∪ b : a,b ∈ L, |a ∩ b prefix| = k-1, last(a) < last(b)}.
        let gen = VecGen { inner: ItemsetGen { universe: 15, max_len: 3 }, max_len: 25 };
        forall(103, 60, &gen, |sets| {
            let mut fixed: Vec<Itemset> =
                sets.iter().filter(|s| s.len() == 2).cloned().collect();
            fixed.sort();
            fixed.dedup();
            if fixed.is_empty() {
                return true;
            }
            let trie = Trie::from_itemsets(2, fixed.iter());
            let mut got = Vec::new();
            trie.self_join(|s| got.push(s.to_vec()));
            let mut expect = Vec::new();
            for a in &fixed {
                for b in &fixed {
                    if a[..1] == b[..1] && a[1] < b[1] {
                        expect.push(vec![a[0], a[1], b[1]]);
                    }
                }
            }
            expect.sort();
            got.sort();
            got == expect
        });
    }

    #[test]
    fn node_count_and_bytes_nonzero() {
        let t = trie_of(2, &[&[1, 2], &[1, 3]]);
        assert_eq!(t.node_count(), 4); // root + {1} + {1,2} + {1,3}
        assert!(t.approx_bytes() > 0);
    }
}
