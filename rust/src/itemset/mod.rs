//! Itemset primitives: item ids, sorted itemsets, the Bodon-style prefix
//! tree (trie) used by every miner for candidate storage/generation/counting,
//! and bitmap encodings for the XLA counting backend.

pub mod bitmap;
pub mod hashtable_trie;
pub mod hashtree;
pub mod trie;

pub use hashtable_trie::HashTableTrie;
pub use hashtree::HashTree;
pub use trie::Trie;

/// An item identifier. Datasets remap raw item labels to dense u32 ids.
pub type Item = u32;

/// A sorted, duplicate-free list of items.
pub type Itemset = Vec<Item>;

/// Returns true iff `xs` is strictly increasing (valid canonical itemset).
pub fn is_canonical(xs: &[Item]) -> bool {
    xs.windows(2).all(|w| w[0] < w[1])
}

/// Canonicalize in place: sort + dedup.
pub fn canonicalize(xs: &mut Itemset) {
    xs.sort_unstable();
    xs.dedup();
}

/// True iff sorted `needle` is a subset of sorted `haystack` (merge walk).
pub fn is_subset(needle: &[Item], haystack: &[Item]) -> bool {
    let mut hi = 0;
    'outer: for &n in needle {
        while hi < haystack.len() {
            match haystack[hi].cmp(&n) {
                std::cmp::Ordering::Less => hi += 1,
                std::cmp::Ordering::Equal => {
                    hi += 1;
                    continue 'outer;
                }
                std::cmp::Ordering::Greater => return false,
            }
        }
        return false;
    }
    true
}

/// Render an itemset for reports: `i1 i3 i9`.
pub fn format_itemset(xs: &[Item]) -> String {
    xs.iter().map(|i| format!("i{i}")).collect::<Vec<_>>().join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_checks() {
        assert!(is_canonical(&[]));
        assert!(is_canonical(&[3]));
        assert!(is_canonical(&[1, 2, 9]));
        assert!(!is_canonical(&[1, 1]));
        assert!(!is_canonical(&[2, 1]));
    }

    #[test]
    fn canonicalize_sorts_and_dedups() {
        let mut v = vec![5, 1, 3, 1, 5];
        canonicalize(&mut v);
        assert_eq!(v, vec![1, 3, 5]);
    }

    #[test]
    fn subset_merge_walk() {
        assert!(is_subset(&[], &[1, 2]));
        assert!(is_subset(&[2], &[1, 2, 3]));
        assert!(is_subset(&[1, 3], &[1, 2, 3]));
        assert!(!is_subset(&[4], &[1, 2, 3]));
        assert!(!is_subset(&[1, 2], &[2, 3]));
        assert!(!is_subset(&[1], &[]));
    }

    #[test]
    fn formatting() {
        assert_eq!(format_itemset(&[1, 4]), "i1 i4");
        assert_eq!(format_itemset(&[]), "");
    }
}
