//! Classic Apriori hash tree (Agrawal & Srikant), one of the three
//! candidate stores compared for MapReduce Apriori by Singh et al.'s
//! data-structure study (the paper's ref [16]): interior nodes hash the
//! next item into `fanout` buckets; leaves hold up to `leaf_cap` itemsets
//! and split when they overflow (unless at maximum depth).
//!
//! Same interface shape as [`super::Trie`] so the counting benches can swap
//! stores; `count_transaction` implements the classic hash-tree subset walk
//! with (item-position) recursion.

use super::{Item, Itemset};

#[derive(Debug, Clone)]
enum NodeKind {
    Interior { children: Vec<Option<u32>> },
    Leaf { sets: Vec<(Itemset, u64)> },
}

#[derive(Debug, Clone)]
struct Node {
    kind: NodeKind,
}

/// Hash tree over fixed-length itemsets.
#[derive(Debug, Clone)]
pub struct HashTree {
    nodes: Vec<Node>,
    k: usize,
    len: usize,
    fanout: usize,
    leaf_cap: usize,
}

const ROOT: u32 = 0;

impl HashTree {
    /// Empty tree for k-itemsets with default fanout/leaf capacity.
    pub fn new(k: usize) -> Self {
        Self::with_params(k, 8, 16)
    }

    /// Empty tree with explicit fanout and leaf capacity.
    pub fn with_params(k: usize, fanout: usize, leaf_cap: usize) -> Self {
        assert!(k >= 1 && fanout >= 2 && leaf_cap >= 1);
        Self {
            nodes: vec![Node { kind: NodeKind::Leaf { sets: Vec::new() } }],
            k,
            len: 0,
            fanout,
            leaf_cap,
        }
    }

    /// Bulk-build from canonical k-itemsets.
    pub fn from_itemsets<'a, I: IntoIterator<Item = &'a Itemset>>(k: usize, sets: I) -> Self {
        let mut t = Self::new(k);
        for s in sets {
            t.insert(s);
        }
        t
    }

    /// The stored itemset length k.
    pub fn level(&self) -> usize {
        self.k
    }

    /// Number of stored itemsets.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the tree stores nothing.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total allocated nodes.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    #[inline]
    fn bucket(&self, item: Item) -> usize {
        item as usize % self.fanout
    }

    /// Insert a canonical itemset. Returns true if newly added.
    pub fn insert(&mut self, set: &[Item]) -> bool {
        debug_assert_eq!(set.len(), self.k);
        debug_assert!(super::is_canonical(set));
        let mut node = ROOT;
        let mut depth = 0usize;
        loop {
            match &mut self.nodes[node as usize].kind {
                NodeKind::Interior { children } => {
                    let b = set[depth] as usize % self.fanout;
                    match children[b] {
                        Some(c) => {
                            node = c;
                            depth += 1;
                        }
                        None => {
                            let id = self.nodes.len() as u32;
                            // Re-borrow after push below; record intent first.
                            self.nodes.push(Node { kind: NodeKind::Leaf { sets: Vec::new() } });
                            if let NodeKind::Interior { children } =
                                &mut self.nodes[node as usize].kind
                            {
                                children[b] = Some(id);
                            }
                            node = id;
                            depth += 1;
                        }
                    }
                }
                NodeKind::Leaf { sets } => {
                    if sets.iter().any(|(s, _)| s == set) {
                        return false;
                    }
                    sets.push((set.to_vec(), 0));
                    self.len += 1;
                    // Split on overflow, but only while more items remain to
                    // hash on (depth < k).
                    if sets.len() > self.leaf_cap && depth < self.k {
                        self.split_leaf(node, depth);
                    }
                    return true;
                }
            }
        }
    }

    fn split_leaf(&mut self, node: u32, depth: usize) {
        let sets = match std::mem::replace(
            &mut self.nodes[node as usize].kind,
            NodeKind::Interior { children: vec![None; self.fanout] },
        ) {
            NodeKind::Leaf { sets } => sets,
            _ => unreachable!("split target must be a leaf"),
        };
        for (set, count) in sets {
            let b = set[depth] as usize % self.fanout;
            let child = {
                let existing = match &self.nodes[node as usize].kind {
                    NodeKind::Interior { children } => children[b],
                    _ => unreachable!(),
                };
                match existing {
                    Some(c) => c,
                    None => {
                        let id = self.nodes.len() as u32;
                        self.nodes.push(Node { kind: NodeKind::Leaf { sets: Vec::new() } });
                        if let NodeKind::Interior { children } = &mut self.nodes[node as usize].kind
                        {
                            children[b] = Some(id);
                        }
                        id
                    }
                }
            };
            if let NodeKind::Leaf { sets } = &mut self.nodes[child as usize].kind {
                sets.push((set, count));
            }
            // Note: recursive overflow is resolved lazily on next insert.
        }
    }

    /// Membership test for a canonical k-itemset.
    pub fn contains(&self, set: &[Item]) -> bool {
        self.find(set).is_some()
    }

    fn find(&self, set: &[Item]) -> Option<(u32, usize)> {
        let mut node = ROOT;
        let mut depth = 0usize;
        loop {
            match &self.nodes[node as usize].kind {
                NodeKind::Interior { children } => {
                    node = children[self.bucket(set[depth])]?;
                    depth += 1;
                }
                NodeKind::Leaf { sets } => {
                    return sets.iter().position(|(s, _)| s == set).map(|i| (node, i));
                }
            }
        }
    }

    /// Support count accumulated for `set` (0 if absent).
    pub fn count_of(&self, set: &[Item]) -> Option<u64> {
        let (node, i) = self.find(set)?;
        match &self.nodes[node as usize].kind {
            NodeKind::Leaf { sets } => Some(sets[i].1),
            _ => None,
        }
    }

    /// Classic hash-tree subset counting: at an interior node at depth `d`,
    /// hash every remaining transaction item and recurse; at a leaf, count
    /// each stored itemset whose first `d` items equal the hashed descent
    /// path and whose remainder is a subset of the transaction suffix.
    /// Transactions are canonical (strictly increasing), so the item path
    /// uniquely identifies the descent — every set is counted exactly once.
    /// Returns `(nodes visited, leaves hit)`.
    pub fn count_transaction(&mut self, txn: &[Item]) -> (u64, u64) {
        let mut visits = 0u64;
        let mut hits = 0u64;
        let mut path: Vec<Item> = Vec::with_capacity(self.k);
        self.walk_count(ROOT, txn, 0, &mut path, &mut visits, &mut hits);
        (visits, hits)
    }

    fn walk_count(
        &mut self,
        node: u32,
        txn: &[Item],
        start: usize,
        path: &mut Vec<Item>,
        visits: &mut u64,
        hits: &mut u64,
    ) {
        *visits += 1;
        // Snapshot interior children to release the borrow before recursing.
        let children: Option<Vec<Option<u32>>> = match &self.nodes[node as usize].kind {
            NodeKind::Interior { children } => Some(children.clone()),
            NodeKind::Leaf { .. } => None,
        };
        match children {
            Some(children) => {
                for pos in start..txn.len() {
                    let b = self.bucket(txn[pos]);
                    if let Some(c) = children[b] {
                        path.push(txn[pos]);
                        self.walk_count(c, txn, pos + 1, path, visits, hits);
                        path.pop();
                    }
                }
            }
            None => {
                let d = path.len();
                // Sets whose remainder must appear within txn[start..].
                let suffix = &txn[start.min(txn.len())..];
                if let NodeKind::Leaf { sets } = &mut self.nodes[node as usize].kind {
                    for (set, count) in sets.iter_mut() {
                        if set.len() >= d
                            && set[..d] == path[..]
                            && super::is_subset(&set[d..], suffix)
                        {
                            *count += 1;
                            *hits += 1;
                        }
                    }
                }
            }
        }
    }

    /// Reset all support counts to zero.
    pub fn clear_counts(&mut self) {
        for n in &mut self.nodes {
            if let NodeKind::Leaf { sets } = &mut n.kind {
                for (_, c) in sets {
                    *c = 0;
                }
            }
        }
    }

    /// All stored `(itemset, count)` pairs, sorted.
    pub fn entries(&self) -> Vec<(Itemset, u64)> {
        let mut out = Vec::with_capacity(self.len);
        for n in &self.nodes {
            if let NodeKind::Leaf { sets } = &n.kind {
                out.extend(sets.iter().cloned());
            }
        }
        out.sort();
        out
    }

    /// Itemsets whose count reaches `min_count`, with counts, sorted.
    pub fn frequent(&self, min_count: u64) -> Vec<(Itemset, u64)> {
        self.entries().into_iter().filter(|(_, c)| *c >= min_count).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::itemset::Trie;
    use crate::util::check::{forall, DbGen};

    fn sets3() -> Vec<Itemset> {
        vec![
            vec![1, 2, 3],
            vec![1, 2, 7],
            vec![1, 5, 9],
            vec![2, 3, 4],
            vec![4, 5, 6],
            vec![6, 7, 8],
            vec![3, 6, 9],
        ]
    }

    #[test]
    fn insert_contains_len() {
        let t = HashTree::from_itemsets(3, sets3().iter());
        assert_eq!(t.len(), 7);
        for s in sets3() {
            assert!(t.contains(&s), "{s:?}");
        }
        assert!(!t.contains(&[1, 2, 4]));
    }

    #[test]
    fn duplicate_insert_ignored() {
        let mut t = HashTree::new(2);
        assert!(t.insert(&[1, 2]));
        assert!(!t.insert(&[1, 2]));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn splitting_under_small_caps() {
        let mut t = HashTree::with_params(3, 2, 1);
        for s in sets3() {
            t.insert(&s);
        }
        assert_eq!(t.len(), 7);
        assert!(t.node_count() > 3, "tree must have split");
        for s in sets3() {
            assert!(t.contains(&s), "{s:?} lost after splits");
        }
    }

    #[test]
    fn counting_matches_trie() {
        let sets = sets3();
        let txns: Vec<Itemset> = vec![
            vec![1, 2, 3, 7],
            vec![1, 2, 5, 7, 9],
            vec![2, 3, 4, 6, 9],
            vec![4, 5, 6, 7, 8],
            (1..=9).collect(),
        ];
        let mut ht = HashTree::with_params(3, 4, 2);
        for s in &sets {
            ht.insert(s);
        }
        let mut trie = Trie::from_itemsets(3, sets.iter());
        for t in &txns {
            ht.count_transaction(t);
            trie.count_transaction(t);
        }
        for s in &sets {
            assert_eq!(ht.count_of(s), trie.count_of(s), "set {s:?}");
        }
    }

    #[test]
    fn prop_counts_match_trie() {
        let gen = DbGen { universe: 12, max_txns: 25, max_width: 7 };
        forall(901, 60, &gen, |db| {
            // Store every 2-subset drawn from the first few transactions.
            let mut sets: Vec<Itemset> = Vec::new();
            for t in db.txns.iter().take(6) {
                for i in 0..t.len() {
                    for j in (i + 1)..t.len() {
                        sets.push(vec![t[i], t[j]]);
                    }
                }
            }
            sets.sort();
            sets.dedup();
            if sets.is_empty() {
                return true;
            }
            let mut ht = HashTree::with_params(2, 3, 2);
            for s in &sets {
                ht.insert(s);
            }
            let mut trie = Trie::from_itemsets(2, sets.iter());
            for t in &db.txns {
                ht.count_transaction(t);
                trie.count_transaction(t);
            }
            sets.iter().all(|s| ht.count_of(s) == trie.count_of(s))
        });
    }

    #[test]
    fn entries_sorted_and_frequent_filter() {
        let mut t = HashTree::new(2);
        t.insert(&[3, 4]);
        t.insert(&[1, 2]);
        t.count_transaction(&[1, 2, 9]);
        let e = t.entries();
        assert_eq!(e[0].0, vec![1, 2]);
        assert_eq!(t.frequent(1), vec![(vec![1, 2], 1)]);
        t.clear_counts();
        assert!(t.frequent(1).is_empty());
    }
}
