//! Counting backends: the trie walk (default, the paper's `subset()`) and
//! the XLA bit-matrix backend running the AOT-compiled Pallas kernel.
//!
//! The XLA backend computes supports for a candidate set over a block of
//! transactions by tiling both into fixed-shape 0/1 matrices and executing
//! `support = Σ_t [T·Cᵀ == |c|]` on the PJRT CPU client. Exactness: all
//! counts are small integers in f32 (< 2^24).

use super::pjrt::PjrtRuntime;
use crate::itemset::bitmap::BitmapTile;
use crate::itemset::{Item, Itemset, Trie};
use anyhow::Result;

/// Strategy for candidate support counting inside a Job2 map task — the
/// selectable per-pass backend knob (`MiningRequest::backend`, CLI
/// `mine --backend`). All backends are byte-identical in mined output
/// (DESIGN.md §11); they differ only in how the per-split counts are
/// computed, and therefore in measured work and simulated time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum CountingBackend {
    /// Recursive trie walk (`subset()` of the paper; the default).
    #[default]
    Trie,
    /// Vertical TID-bitmap: per-item [`crate::itemset::bitmap::BitVec64`]
    /// TID-lists built once per split, candidates counted by cache-blocked
    /// u64 AND+popcount over their items' rows.
    Bitmap,
    /// Dense triangular pair matrix (paper ref [6]) — k = 2 passes only;
    /// other passes of the same request fall back to the trie walk.
    Triangular,
    /// Per-pass pick driven by the cluster cost model: estimate each
    /// applicable backend's map compute from candidate count × dataset
    /// density and take the cheapest (DESIGN.md §11).
    Auto,
}

impl CountingBackend {
    /// All selectable backends, in CLI presentation order.
    pub const ALL: [CountingBackend; 4] = [
        CountingBackend::Trie,
        CountingBackend::Bitmap,
        CountingBackend::Triangular,
        CountingBackend::Auto,
    ];

    /// The backend's CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            CountingBackend::Trie => "trie",
            CountingBackend::Bitmap => "bitmap",
            CountingBackend::Triangular => "triangular",
            CountingBackend::Auto => "auto",
        }
    }

    /// Parse a backend name (case- and punctuation-insensitive). The
    /// trait-based spellings — `s.parse::<CountingBackend>()` or
    /// `CountingBackend::try_from(s)` — carry a typed
    /// [`ParseBackendError`]; this is their shared `Option`-shaped core.
    pub fn parse(s: &str) -> Option<CountingBackend> {
        let norm = s.to_ascii_lowercase().replace(['-', '_'], "");
        Some(match norm.as_str() {
            "trie" => CountingBackend::Trie,
            "bitmap" | "tidbitmap" => CountingBackend::Bitmap,
            "triangular" | "triangle" => CountingBackend::Triangular,
            "auto" => CountingBackend::Auto,
            _ => return None,
        })
    }
}

impl std::fmt::Display for CountingBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Error of parsing a [`CountingBackend`] name: carries the rejected input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseBackendError(
    /// The input string that matched no backend name.
    pub String,
);

impl std::fmt::Display for ParseBackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown counting backend {:?}; expected one of trie, bitmap, triangular, auto",
            self.0
        )
    }
}

impl std::error::Error for ParseBackendError {}

impl std::str::FromStr for CountingBackend {
    type Err = ParseBackendError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        CountingBackend::parse(s).ok_or_else(|| ParseBackendError(s.to_string()))
    }
}

impl TryFrom<&str> for CountingBackend {
    type Error = ParseBackendError;

    fn try_from(s: &str) -> Result<Self, Self::Error> {
        s.parse()
    }
}

/// Support counting via the compiled XLA tile executable.
pub struct XlaCounter {
    runtime: PjrtRuntime,
}

impl XlaCounter {
    /// Wrap a loaded runtime.
    pub fn new(runtime: PjrtRuntime) -> Self {
        Self { runtime }
    }

    /// The underlying tile runtime.
    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }

    /// Count supports of `cands` over `txns`. Items must be < item_width.
    /// Returns one count per candidate, in the order given.
    pub fn count(&self, cands: &[Itemset], txns: &[Itemset]) -> Result<Vec<u64>> {
        let spec = self.runtime.spec;
        let mut supports = vec![0u64; cands.len()];
        let cand_refs: Vec<&[Item]> = cands.iter().map(|c| c.as_slice()).collect();
        for cchunk_idx in 0..cand_refs.len().div_ceil(spec.cand_tile) {
            let clo = cchunk_idx * spec.cand_tile;
            let chi = (clo + spec.cand_tile).min(cand_refs.len());
            let cslice = &cand_refs[clo..chi];
            let ctile = BitmapTile::encode(cslice, spec.cand_tile, spec.item_width)?;
            let lens = BitmapTile::lengths_with_sentinel(cslice, spec.cand_tile, spec.item_width);
            for tchunk in txns.chunks(spec.txn_tile) {
                let trefs: Vec<&[Item]> = tchunk.iter().map(|t| t.as_slice()).collect();
                let ttile = BitmapTile::encode(&trefs, spec.txn_tile, spec.item_width)?;
                let out = self.runtime.support_tile(&ttile.data, &ctile.data, &lens)?;
                for (i, s) in out.iter().take(chi - clo).enumerate() {
                    supports[clo + i] += *s as u64;
                }
            }
        }
        Ok(supports)
    }

    /// Count supports for every itemset stored in `trie` (iteration order),
    /// returning `(itemset, count)` pairs — a drop-in for the trie walk.
    pub fn count_trie(&self, trie: &Trie, txns: &[Itemset]) -> Result<Vec<(Itemset, u64)>> {
        let sets = trie.itemsets();
        let counts = self.count(&sets, txns)?;
        Ok(sets.into_iter().zip(counts).collect())
    }
}

/// Pure-rust reference for the XLA tile semantics (used by tests and by the
/// native vectorized fallback): subset counting over u64 bitsets.
pub fn count_bitset_reference(cands: &[Itemset], txns: &[Itemset], width: usize) -> Vec<u64> {
    use crate::itemset::bitmap::BitVec64;
    let cbits: Vec<BitVec64> = cands.iter().map(|c| BitVec64::from_set(c, width)).collect();
    let mut out = vec![0u64; cands.len()];
    for t in txns {
        let tb = BitVec64::from_set(t, width);
        for (i, cb) in cbits.iter().enumerate() {
            if cb.is_subset_of(&tb) {
                out[i] += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitset_reference_agrees_with_trie() {
        let cands: Vec<Itemset> = vec![vec![0, 1], vec![1, 2], vec![0, 3]];
        let txns: Vec<Itemset> = vec![vec![0, 1, 2], vec![1, 2], vec![0, 1, 3]];
        let by_bits = count_bitset_reference(&cands, &txns, 8);
        let mut trie = Trie::from_itemsets(2, cands.iter());
        for t in &txns {
            trie.count_transaction(t);
        }
        let by_trie: Vec<u64> = cands.iter().map(|c| trie.count_of(c).unwrap()).collect();
        assert_eq!(by_bits, by_trie);
        assert_eq!(by_bits, vec![2, 2, 1]);
    }

    #[test]
    fn backend_default_is_trie() {
        assert_eq!(CountingBackend::default(), CountingBackend::Trie);
    }

    #[test]
    fn backend_parse_roundtrip() {
        for b in CountingBackend::ALL {
            assert_eq!(CountingBackend::parse(b.name()), Some(b));
            assert_eq!(b.name().parse::<CountingBackend>(), Ok(b));
            assert_eq!(CountingBackend::try_from(b.to_string().as_str()), Ok(b));
        }
        assert_eq!(CountingBackend::parse("TID-bitmap"), Some(CountingBackend::Bitmap));
        assert_eq!(CountingBackend::parse("Triangle"), Some(CountingBackend::Triangular));
        let err = "nope".parse::<CountingBackend>().expect_err("unknown name must error");
        assert_eq!(err, ParseBackendError("nope".into()));
        let msg = err.to_string();
        assert!(msg.contains("unknown counting backend") && msg.contains("bitmap"), "{msg}");
    }
}
