//! Loader/executor for the AOT-compiled support-count artifact: artifact
//! discovery plus a typed `support_tile` entry point.
//!
//! Two interchangeable backends sit behind the same `PjrtRuntime` API:
//!
//! * `--features xla-pjrt`: the real PJRT CPU client through the `xla`
//!   crate (compilation caching, HLO-text parsing). The crate is not
//!   available in the offline build environment, so enabling the feature
//!   requires adding the dependency by hand.
//! * default: a native interpreter executing the artifact's tile semantics
//!   (`S = T · Cᵀ` over 0/1 f32 matrices; `support[c] += [S[t, c] == |c|]`)
//!   in pure Rust. Counts are small integers in f32 (< 2^24), so the two
//!   backends are numerically identical — the `rust/tests/runtime_xla.rs`
//!   suite checks both against the u64-bitset reference.

use anyhow::{bail, Context as _, Result};
use std::path::{Path, PathBuf};

/// Shape signature of a compiled support-count artifact. File naming
/// convention (see python/compile/aot.py):
/// `support_count_t{T}_i{I}_c{C}.hlo.txt`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArtifactSpec {
    /// Transactions per tile.
    pub txn_tile: usize,
    /// Item (bitmap) width.
    pub item_width: usize,
    /// Candidates per tile.
    pub cand_tile: usize,
}

impl ArtifactSpec {
    /// The default tile compiled by `make artifacts`.
    pub const DEFAULT: ArtifactSpec =
        ArtifactSpec { txn_tile: 256, item_width: 256, cand_tile: 256 };

    /// Artifact file name for this tile shape.
    pub fn file_name(&self) -> String {
        format!(
            "support_count_t{}_i{}_c{}.hlo.txt",
            self.txn_tile, self.item_width, self.cand_tile
        )
    }
}

/// Locate the artifacts directory: `$MRAPRIORI_ARTIFACTS`, else
/// `./artifacts`, else `artifacts/` next to the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("MRAPRIORI_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    // Fall back to the crate manifest dir (useful under `cargo test`).
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A PJRT CPU client holding one compiled support-count executable.
#[cfg(feature = "xla-pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    /// Tile shape of the loaded executable.
    pub spec: ArtifactSpec,
}

#[cfg(feature = "xla-pjrt")]
impl PjrtRuntime {
    /// Load and compile the artifact for `spec` from `dir`.
    pub fn load(dir: &Path, spec: ArtifactSpec) -> Result<Self> {
        let path = dir.join(spec.file_name());
        if !path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            );
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path is not UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling HLO module")?;
        Ok(Self { client, exe, spec })
    }

    /// Load the default artifact from the default directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir(), ArtifactSpec::DEFAULT)
    }

    /// PJRT platform name of the client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one tile: `txns` is a row-major (T × I) 0/1 matrix, `cands`
    /// a (C × I) matrix, `lengths` a C-vector of candidate lengths (padding
    /// rows carry an unmatchable sentinel). Returns per-candidate supports
    /// over the valid transaction rows.
    pub fn support_tile(&self, txns: &[f32], cands: &[f32], lengths: &[f32]) -> Result<Vec<f32>> {
        let s = &self.spec;
        anyhow::ensure!(txns.len() == s.txn_tile * s.item_width, "txns buffer shape");
        anyhow::ensure!(cands.len() == s.cand_tile * s.item_width, "cands buffer shape");
        anyhow::ensure!(lengths.len() == s.cand_tile, "lengths buffer shape");
        let t = xla::Literal::vec1(txns).reshape(&[s.txn_tile as i64, s.item_width as i64])?;
        let c = xla::Literal::vec1(cands).reshape(&[s.cand_tile as i64, s.item_width as i64])?;
        let l = xla::Literal::vec1(lengths);
        let result = self.exe.execute::<xla::Literal>(&[t, c, l])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> 1-tuple of f32[C].
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Native interpreter for the support-count artifact (default backend):
/// executes the tile's semantics directly rather than through PJRT.
#[cfg(not(feature = "xla-pjrt"))]
pub struct PjrtRuntime {
    /// Tile shape of the loaded artifact.
    pub spec: ArtifactSpec,
}

#[cfg(not(feature = "xla-pjrt"))]
impl PjrtRuntime {
    /// Load the artifact for `spec` from `dir`. The interpreter derives the
    /// tile program from `spec` alone, but still requires the artifact file
    /// to exist and be well-formed so both backends share one contract.
    pub fn load(dir: &Path, spec: ArtifactSpec) -> Result<Self> {
        let path = dir.join(spec.file_name());
        if !path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            );
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading HLO text {}", path.display()))?;
        if !text.contains("HloModule") {
            bail!("{} does not look like an HLO text artifact", path.display());
        }
        Ok(Self { spec })
    }

    /// Load the default artifact from the default directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir(), ArtifactSpec::DEFAULT)
    }

    /// Backend platform name (always "cpu").
    pub fn platform(&self) -> String {
        "cpu".to_string()
    }

    /// Execute one tile: `txns` is a row-major (T × I) 0/1 matrix, `cands`
    /// a (C × I) matrix, `lengths` a C-vector of candidate lengths (padding
    /// rows carry an unmatchable sentinel). Returns per-candidate supports
    /// over the valid transaction rows — the exact semantics of the
    /// compiled kernel: `support[c] = Σ_t [⟨txns[t], cands[c]⟩ == lengths[c]]`.
    pub fn support_tile(&self, txns: &[f32], cands: &[f32], lengths: &[f32]) -> Result<Vec<f32>> {
        let s = &self.spec;
        anyhow::ensure!(txns.len() == s.txn_tile * s.item_width, "txns buffer shape");
        anyhow::ensure!(cands.len() == s.cand_tile * s.item_width, "cands buffer shape");
        anyhow::ensure!(lengths.len() == s.cand_tile, "lengths buffer shape");
        let width = s.item_width;
        let mut out = vec![0f32; s.cand_tile];
        for (support, (crow, len)) in
            out.iter_mut().zip(cands.chunks_exact(width).zip(lengths))
        {
            for trow in txns.chunks_exact(width) {
                let dot: f32 = trow.iter().zip(crow).map(|(t, c)| t * c).sum();
                if dot == *len {
                    *support += 1.0;
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_file_name() {
        assert_eq!(
            ArtifactSpec::DEFAULT.file_name(),
            "support_count_t256_i256_c256.hlo.txt"
        );
    }

    #[test]
    fn missing_artifact_is_reported() {
        let dir = std::env::temp_dir().join("mrapriori_no_artifacts");
        let err = match PjrtRuntime::load(&dir, ArtifactSpec::DEFAULT) {
            Err(e) => e,
            Ok(_) => panic!("load must fail without artifacts"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    /// The interpreter must implement the kernel's dot-vs-length rule,
    /// including the padding-row sentinel (see BitmapTile).
    #[cfg(not(feature = "xla-pjrt"))]
    #[test]
    fn native_tile_counts_with_sentinel() {
        let spec = ArtifactSpec { txn_tile: 3, item_width: 4, cand_tile: 2 };
        let rt = PjrtRuntime { spec };
        // txns: {0,1}, {1,2}, {0,1,2}; cands: {0,1}, padding (sentinel 5).
        #[rustfmt::skip]
        let txns = vec![
            1.0, 1.0, 0.0, 0.0,
            0.0, 1.0, 1.0, 0.0,
            1.0, 1.0, 1.0, 0.0,
        ];
        #[rustfmt::skip]
        let cands = vec![
            1.0, 1.0, 0.0, 0.0,
            0.0, 0.0, 0.0, 0.0,
        ];
        let lengths = vec![2.0, 5.0];
        let out = rt.support_tile(&txns, &cands, &lengths).unwrap();
        assert_eq!(out, vec![2.0, 0.0]); // {0,1} ⊆ txns 0 and 2; padding never counts
        // Shape mismatches are rejected.
        assert!(rt.support_tile(&txns[1..], &cands, &lengths).is_err());
    }

    // Execution tests live in rust/tests/runtime_xla.rs (they need the
    // artifacts built by `make artifacts`).
}
