//! XLA/PJRT runtime: loads the AOT-compiled support-counting executable
//! (authored in JAX/Pallas, lowered to HLO text by `python/compile/aot.py`)
//! and exposes it as an alternative counting backend for the mappers.
//!
//! Interchange is HLO **text**: jax >= 0.5 emits HloModuleProto with 64-bit
//! instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The real PJRT client sits behind the `xla-pjrt` cargo feature (the `xla`
//! crate is unavailable offline); the default build executes the artifact's
//! tile semantics through a numerically identical native interpreter — see
//! [`pjrt`] and DESIGN.md §5.

pub mod counting;
pub mod pjrt;

pub use counting::{CountingBackend, ParseBackendError, XlaCounter};
pub use pjrt::{ArtifactSpec, PjrtRuntime};
