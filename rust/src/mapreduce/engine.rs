//! The MapReduce execution engine: runs map tasks over input splits
//! (optionally on real threads), applies the combiner, shuffles by
//! partition, runs reduce tasks, and meters everything for the cluster
//! simulator.
//!
//! The engine executes *real* work — mappers genuinely generate candidates
//! and count supports — while the per-task [`TaskMeter`]s feed the
//! deterministic cost model in [`crate::cluster`] that turns measured
//! operation counts into simulated cluster seconds.

use super::api::{Combiner, Context, Mapper, Partitioner, Reducer};
use super::counters::{keys, Counters};
use crate::hdfs::InputSplit;
use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

/// Per-task measurement record consumed by the cluster scheduler.
#[derive(Debug, Clone)]
pub struct TaskMeter {
    pub task_id: usize,
    pub counters: Counters,
    /// Locality hint from the task's input split (empty for reduce tasks).
    pub preferred_nodes: Vec<usize>,
    /// Real wall-clock seconds this task took on the host machine.
    pub wall_secs: f64,
}

/// Everything a finished job reports back to its driver.
#[derive(Debug)]
pub struct JobOutput<O> {
    pub outputs: Vec<O>,
    pub counters: Counters,
    pub map_meters: Vec<TaskMeter>,
    pub reduce_meters: Vec<TaskMeter>,
    /// Driver side-channel values (max across tasks — every map task of an
    /// Apriori job computes the same `candidateCount`/`npass`).
    pub aux: BTreeMap<&'static str, u64>,
}

/// A configured job, ready to run. Mirrors Hadoop's `Job` object.
pub struct JobSpec<'a, M: Mapper, R> {
    pub name: String,
    pub splits: Vec<InputSplit>,
    /// Builds the mapper instance for task `i` (Hadoop constructs one Mapper
    /// per split); runs on the task's thread.
    pub mapper_factory: Box<dyn Fn(usize) -> M + Send + Sync + 'a>,
    pub combiner: Option<Box<dyn Combiner<M::K, M::V> + 'a>>,
    pub reducer: R,
    pub partitioner: Box<dyn Partitioner<M::K> + 'a>,
    pub n_reducers: usize,
    /// Host threads for real execution (not simulated slots!). On the
    /// single-core CI box this is 1; the simulator models cluster
    /// parallelism independently of host parallelism.
    pub workers: usize,
}

struct MapTaskResult<K, V> {
    meter: TaskMeter,
    pairs: Vec<(K, V)>,
    aux: BTreeMap<&'static str, u64>,
}

/// Run one job to completion.
pub fn run_job<M, R, O>(spec: JobSpec<'_, M, R>) -> JobOutput<O>
where
    M: Mapper,
    R: Reducer<M::K, M::V, Out = O>,
    O: Send,
{
    let JobSpec { name: _, splits, mapper_factory, combiner, reducer, partitioner, n_reducers, workers } =
        spec;
    let n_reducers = n_reducers.max(1);

    // ---- map (+ combine) phase -----------------------------------------
    let factory = &mapper_factory;
    let combiner_ref = combiner.as_deref();
    let run_one = |task_id: usize, split: &InputSplit| -> MapTaskResult<M::K, M::V> {
        let start = Instant::now();
        let mut mapper = factory(task_id);
        let mut ctx: Context<M::K, M::V> = Context::new();
        ctx.counters.add(keys::MAP_INPUT_RECORDS, split.len() as u64);
        for (offset, record) in split.iter() {
            mapper.map(offset, record, &mut ctx);
        }
        mapper.cleanup(&mut ctx);
        let mut pairs = ctx.take_output();
        // Combine stage (map-side): fold values per key locally.
        if let Some(c) = combiner_ref {
            pairs = combine_pairs(c, pairs);
        }
        ctx.counters.add(keys::COMBINE_OUTPUT_TUPLES, pairs.len() as u64);
        MapTaskResult {
            meter: TaskMeter {
                task_id,
                counters: ctx.counters,
                preferred_nodes: split.preferred_nodes.clone(),
                wall_secs: start.elapsed().as_secs_f64(),
            },
            pairs,
            aux: ctx.aux,
        }
    };

    let map_results: Vec<MapTaskResult<M::K, M::V>> = if workers <= 1 || splits.len() <= 1 {
        splits.iter().enumerate().map(|(i, s)| run_one(i, s)).collect()
    } else {
        // Scoped threads so the factory/combiner may borrow from the driver.
        let mut slots: Vec<Option<MapTaskResult<M::K, M::V>>> =
            (0..splits.len()).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (chunk_idx, chunk) in splits.chunks(splits.len().div_ceil(workers)).enumerate() {
                let base = chunk_idx * splits.len().div_ceil(workers);
                let run_one = &run_one;
                handles.push(scope.spawn(move || {
                    chunk
                        .iter()
                        .enumerate()
                        .map(|(j, s)| (base + j, run_one(base + j, s)))
                        .collect::<Vec<_>>()
                }));
            }
            for h in handles {
                for (i, r) in h.join().expect("map task panicked") {
                    slots[i] = Some(r);
                }
            }
        });
        slots.into_iter().map(|s| s.expect("missing map task result")).collect()
    };

    // ---- aggregate map side ---------------------------------------------
    let mut counters = Counters::new();
    let mut aux: BTreeMap<&'static str, u64> = BTreeMap::new();
    let mut map_meters = Vec::with_capacity(map_results.len());
    // Hash-grouped shuffle per partition. (A Hadoop-style sort-merge
    // variant was tried and reverted: sorting flat pair vectors measured
    // ~25% slower end-to-end than BTreeMap insertion here — §Perf log.)
    let mut buckets: Vec<BTreeMap<M::K, Vec<M::V>>> =
        (0..n_reducers).map(|_| BTreeMap::new()).collect();
    for result in map_results {
        counters.merge(&result.meter.counters);
        for (k, v) in &result.aux {
            let slot = aux.entry(k).or_insert(0);
            *slot = (*slot).max(*v);
        }
        for (k, v) in result.pairs {
            let p = partitioner.partition(&k, n_reducers);
            buckets[p].entry(k).or_default().push(v);
        }
        map_meters.push(result.meter);
    }

    // ---- reduce phase -----------------------------------------------------
    let mut outputs = Vec::new();
    let mut reduce_meters = Vec::with_capacity(n_reducers);
    for (rid, bucket) in buckets.into_iter().enumerate() {
        let start = Instant::now();
        let mut rc = Counters::new();
        let in_tuples: u64 = bucket.values().map(|v| v.len() as u64).sum();
        rc.add(keys::REDUCE_INPUT_TUPLES, in_tuples);
        let mut out_records = 0u64;
        for (k, vs) in &bucket {
            if let Some(o) = reducer.reduce(k, vs) {
                outputs.push(o);
                out_records += 1;
            }
        }
        rc.add(keys::REDUCE_OUTPUT_RECORDS, out_records);
        counters.merge(&rc);
        reduce_meters.push(TaskMeter {
            task_id: rid,
            counters: rc,
            preferred_nodes: Vec::new(),
            wall_secs: start.elapsed().as_secs_f64(),
        });
    }

    JobOutput { outputs, counters, map_meters, reduce_meters, aux }
}

fn combine_pairs<K: Ord + Clone + std::hash::Hash, V, C: Combiner<K, V> + ?Sized>(
    combiner: &C,
    pairs: Vec<(K, V)>,
) -> Vec<(K, V)> {
    let mut grouped: HashMap<K, Vec<V>> = HashMap::with_capacity(pairs.len() / 2 + 1);
    for (k, v) in pairs {
        grouped.entry(k).or_default().push(v);
    }
    let mut out: Vec<(K, V)> = grouped
        .into_iter()
        .map(|(k, mut vs)| {
            let v = combiner.combine(&k, &mut vs);
            (k, v)
        })
        .collect();
    // Deterministic downstream order regardless of hash iteration.
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::TransactionDb;
    use crate::hdfs;
    use crate::itemset::Itemset;
    use crate::mapreduce::api::{HashPartitioner, MinSupportReducer, SumCombiner};

    /// Word-count analog: emit (item, 1) per item — the paper's Job1 mapper.
    struct ItemMapper;
    impl Mapper for ItemMapper {
        type K = u32;
        type V = u64;
        fn map(&mut self, _off: usize, record: &Itemset, ctx: &mut Context<u32, u64>) {
            for &i in record {
                ctx.write(i, 1);
            }
        }
    }

    fn splits_for(db: &TransactionDb, per_split: usize) -> Vec<InputSplit> {
        let f = hdfs::put(db, per_split, 4, 3, 1);
        hdfs::nline_splits(&f, per_split)
    }

    fn demo_db() -> TransactionDb {
        TransactionDb::new(
            "d",
            4,
            vec![vec![0, 1], vec![0, 2], vec![0, 1, 3], vec![1], vec![0]],
        )
    }

    fn run_wordcount(workers: usize, n_reducers: usize, min_count: u64) -> JobOutput<(u32, u64)> {
        let db = demo_db();
        run_job(JobSpec {
            name: "wc".into(),
            splits: splits_for(&db, 2),
            mapper_factory: Box::new(|_| ItemMapper),
            combiner: Some(Box::new(SumCombiner)),
            reducer: MinSupportReducer { min_count },
            partitioner: Box::new(HashPartitioner),
            n_reducers,
            workers,
        })
    }

    fn sorted(mut v: Vec<(u32, u64)>) -> Vec<(u32, u64)> {
        v.sort();
        v
    }

    #[test]
    fn wordcount_correct() {
        let out = run_wordcount(1, 2, 1);
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3), (2, 1), (3, 1)]);
    }

    #[test]
    fn min_support_filter_applies() {
        let out = run_wordcount(1, 2, 3);
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3)]);
    }

    #[test]
    fn parallel_equals_sequential() {
        let seq = sorted(run_wordcount(1, 3, 1).outputs);
        let par = sorted(run_wordcount(4, 3, 1).outputs);
        assert_eq!(seq, par);
    }

    #[test]
    fn counters_account_for_combine() {
        let out = run_wordcount(1, 1, 1);
        assert_eq!(out.counters.get(keys::MAP_INPUT_RECORDS), 5);
        assert_eq!(out.counters.get(keys::MAP_OUTPUT_TUPLES), 9); // raw item writes
        // 3 splits: {01,02}->(0:2,1:1,2:1)=3, {013,1}->(0:1,1:2,3:1)=3, {0}->1
        assert_eq!(out.counters.get(keys::COMBINE_OUTPUT_TUPLES), 7);
        assert_eq!(out.counters.get(keys::REDUCE_INPUT_TUPLES), 7);
        assert_eq!(out.counters.get(keys::REDUCE_OUTPUT_RECORDS), 4);
    }

    #[test]
    fn task_meters_present() {
        let out = run_wordcount(1, 2, 1);
        assert_eq!(out.map_meters.len(), 3);
        assert_eq!(out.reduce_meters.len(), 2);
        assert!(out.map_meters.iter().all(|m| m.wall_secs >= 0.0));
        assert!(!out.map_meters[0].preferred_nodes.is_empty());
    }

    #[test]
    fn reducer_count_respected() {
        let out = run_wordcount(1, 4, 1);
        assert_eq!(out.reduce_meters.len(), 4);
        let total: u64 =
            out.reduce_meters.iter().map(|m| m.counters.get(keys::REDUCE_INPUT_TUPLES)).sum();
        assert_eq!(total, 7);
    }

    /// Mapper that reports through the aux side-channel.
    struct AuxMapper(u64);
    impl Mapper for AuxMapper {
        type K = u32;
        type V = u64;
        fn map(&mut self, _o: usize, _r: &Itemset, _c: &mut Context<u32, u64>) {}
        fn cleanup(&mut self, ctx: &mut Context<u32, u64>) {
            ctx.set_aux(keys::CANDIDATES, self.0);
        }
    }

    #[test]
    fn aux_takes_max_across_tasks() {
        let db = demo_db();
        let out = run_job(JobSpec {
            name: "aux".into(),
            splits: splits_for(&db, 2),
            mapper_factory: Box::new(|task| AuxMapper(10 + task as u64)),
            combiner: None,
            reducer: MinSupportReducer { min_count: 1 },
            partitioner: Box::new(HashPartitioner),
            n_reducers: 1,
            workers: 1,
        });
        assert_eq!(out.aux.get(keys::CANDIDATES), Some(&12)); // 3 tasks: 10,11,12
    }

    #[test]
    fn no_combiner_shuffles_raw_tuples() {
        let db = demo_db();
        let out = run_job(JobSpec {
            name: "raw".into(),
            splits: splits_for(&db, 2),
            mapper_factory: Box::new(|_| ItemMapper),
            combiner: None,
            reducer: MinSupportReducer { min_count: 1 },
            partitioner: Box::new(HashPartitioner),
            n_reducers: 2,
            workers: 1,
        });
        assert_eq!(out.counters.get(keys::COMBINE_OUTPUT_TUPLES), 9); // = raw
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3), (2, 1), (3, 1)]);
    }
}
