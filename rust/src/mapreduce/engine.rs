//! The MapReduce execution engine: runs map tasks over input splits,
//! applies the combiner and partitioner *inside each map task* (map-side
//! partitioned spills, as Hadoop's sort/spill stage does), hands each
//! reduce task its column of spill buckets to merge and reduce on real
//! threads, and meters everything for the cluster simulator. The driver's
//! only serial work between the phases is a bucket transpose.
//!
//! The engine executes *real* work — mappers genuinely generate candidates
//! and count supports — while the per-task [`TaskMeter`]s feed the
//! deterministic cost model in [`crate::cluster`] that turns measured
//! operation counts into simulated cluster seconds.
//!
//! `JobSpec::workers` is the host-thread budget for the WHOLE job: both map
//! and reduce tasks execute on the scoped batch runner in
//! [`crate::util::pool`], and outputs are deterministic regardless of the
//! worker count (spills are pre-sorted, reduce outputs are concatenated in
//! task order). See DESIGN.md §4.

use super::api::{Combiner, Context, Mapper, Partitioner, Reducer};
use super::counters::{keys, Counters};
use crate::hdfs::InputSplit;
use crate::util::pool;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Instant;

/// Per-task measurement record consumed by the cluster scheduler.
#[derive(Debug, Clone)]
pub struct TaskMeter {
    /// Task index within its phase (map and reduce number independently).
    pub task_id: usize,
    /// Name of the job this task belongs to (phase attribution in reports).
    pub job: Arc<str>,
    /// The task's operation counters.
    pub counters: Counters,
    /// Locality hint from the task's input split (empty for reduce tasks).
    pub preferred_nodes: Vec<usize>,
    /// Real wall-clock seconds this task took on the host machine.
    pub wall_secs: f64,
}

/// Everything a finished job reports back to its driver.
#[derive(Debug)]
pub struct JobOutput<O> {
    /// The `JobSpec::name` this output belongs to.
    pub name: String,
    /// Reduce outputs, concatenated in reduce-task order.
    pub outputs: Vec<O>,
    /// Merged counters across all map and reduce tasks.
    pub counters: Counters,
    /// One meter per map task.
    pub map_meters: Vec<TaskMeter>,
    /// One meter per reduce task.
    pub reduce_meters: Vec<TaskMeter>,
    /// Driver side-channel values (max across tasks — every map task of an
    /// Apriori job computes the same `candidateCount`/`npass`).
    pub aux: BTreeMap<&'static str, u64>,
    /// Aux keys whose values DIVERGED across map tasks (the max still
    /// wins, for backward compatibility). An Apriori driver treats any
    /// entry here as a bug — see the `debug_assert!` in
    /// [`crate::coordinator::run_with`] — but generic jobs may legally
    /// report per-task values.
    pub aux_divergence: Vec<&'static str>,
}

/// A configured job, ready to run. Mirrors Hadoop's `Job` object.
pub struct JobSpec<'a, M: Mapper, R> {
    /// Job name (flows into meters and phase records).
    pub name: String,
    /// Input splits; one map task each.
    pub splits: Vec<InputSplit>,
    /// Builds the mapper instance for task `i` (Hadoop constructs one Mapper
    /// per split); runs on the task's thread.
    pub mapper_factory: Box<dyn Fn(usize) -> M + Send + Sync + 'a>,
    /// Optional map-side combiner.
    pub combiner: Option<Box<dyn Combiner<M::K, M::V> + 'a>>,
    /// The reduce function (shared read-only across tasks).
    pub reducer: R,
    /// Key -> reducer routing.
    pub partitioner: Box<dyn Partitioner<M::K> + 'a>,
    /// Number of reduce tasks (clamped to >= 1).
    pub n_reducers: usize,
    /// Host threads for real execution (not simulated slots!) of both the
    /// map AND reduce phases. On the single-core CI box this is 1; the
    /// simulator models cluster parallelism independently of host
    /// parallelism.
    pub workers: usize,
}

struct MapTaskResult<K, V> {
    meter: TaskMeter,
    /// One pre-combined, pre-sorted spill bucket per reducer.
    buckets: Vec<Vec<(K, V)>>,
    aux: BTreeMap<&'static str, u64>,
}

/// Run one job to completion.
pub fn run_job<M, R, O>(spec: JobSpec<'_, M, R>) -> JobOutput<O>
where
    M: Mapper,
    R: Reducer<M::K, M::V, Out = O>,
    O: Send,
{
    let JobSpec { name, splits, mapper_factory, combiner, reducer, partitioner, n_reducers, workers } =
        spec;
    let n_reducers = n_reducers.max(1);
    let job: Arc<str> = Arc::from(name.as_str());
    let job_start = Instant::now();

    // ---- map (+ combine + partition) phase ------------------------------
    let factory = &mapper_factory;
    let combiner_ref = combiner.as_deref();
    let partitioner_ref = &*partitioner;
    let job_name = &job;
    let run_map_task = |task_id: usize, split: &InputSplit| -> MapTaskResult<M::K, M::V> {
        let start = Instant::now();
        let mut mapper = factory(task_id);
        let mut ctx: Context<M::K, M::V> = Context::new();
        ctx.counters.add(keys::MAP_INPUT_RECORDS, split.len() as u64);
        // RecordReader loop: the split streams records from its backing
        // RecordSource (zero-copy for in-memory files; one decoded block at
        // a time for segment stores, so task memory is bounded by the HDFS
        // block size rather than the dataset size).
        split.for_each_record(|offset, record| mapper.map(offset, record, &mut ctx));
        mapper.cleanup(&mut ctx);
        // Map-side partitioned spill: route every pair to its reducer's
        // bucket HERE, on the task's own thread, then combine each bucket
        // locally. The driver never re-partitions a flat pair stream — it
        // only concatenates per-reducer buckets, like a real shuffle
        // fetching per-partition spill files. (A key always lands in one
        // partition, so partition-then-combine aggregates exactly like the
        // old combine-then-partition order did.)
        let mut buckets: Vec<Vec<(M::K, M::V)>> = (0..n_reducers).map(|_| Vec::new()).collect();
        for (k, v) in ctx.take_output() {
            let p = partitioner_ref.partition(&k, n_reducers);
            buckets[p].push((k, v));
        }
        let mut spilled = 0u64;
        for bucket in &mut buckets {
            if let Some(c) = combiner_ref {
                // Combine stage (map-side): fold values per key locally.
                // Sorts the bucket as a side effect (deterministic spills).
                *bucket = combine_pairs(c, std::mem::take(bucket));
            }
            // Without a combiner the raw emission order is kept — generic
            // reducers may be order-sensitive.
            spilled += bucket.len() as u64;
        }
        ctx.counters.add(keys::COMBINE_OUTPUT_TUPLES, spilled);
        ctx.counters.add(
            keys::SHUFFLE_SPILL_PARTITIONS,
            buckets.iter().filter(|b| !b.is_empty()).count() as u64,
        );
        MapTaskResult {
            meter: TaskMeter {
                task_id,
                job: Arc::clone(job_name),
                counters: ctx.counters,
                preferred_nodes: split.preferred_nodes.clone(),
                wall_secs: start.elapsed().as_secs_f64(),
            },
            buckets,
            aux: ctx.aux,
        }
    };

    let map_results: Vec<MapTaskResult<M::K, M::V>> = {
        let run_map_task = &run_map_task;
        let map_jobs: Vec<_> =
            splits.iter().enumerate().map(|(i, s)| move || run_map_task(i, s)).collect();
        pool::run_batch_scoped(workers, map_jobs)
    };

    // ---- aggregate map side ---------------------------------------------
    let n_map_tasks = map_results.len();
    let mut counters = Counters::new();
    let mut aux: BTreeMap<&'static str, u64> = BTreeMap::new();
    let mut aux_divergence: Vec<&'static str> = Vec::new();
    let mut map_meters = Vec::with_capacity(n_map_tasks);
    // Transpose the task-major spills into reducer-major columns. This is
    // the ONLY serial work between the two threaded phases — a Vec move per
    // (task, reducer) pair; the per-key grouping happens inside each
    // (threaded) reduce task below.
    let mut columns: Vec<Vec<Vec<(M::K, M::V)>>> =
        (0..n_reducers).map(|_| Vec::with_capacity(n_map_tasks)).collect();
    for result in map_results {
        let MapTaskResult { meter, buckets, aux: task_aux } = result;
        counters.merge(&meter.counters);
        for (k, v) in task_aux {
            if let Some(prev) = aux.get(k) {
                if *prev != v && !aux_divergence.contains(&k) {
                    aux_divergence.push(k);
                }
            }
            let slot = aux.entry(k).or_insert(0);
            *slot = (*slot).max(v);
        }
        for (column, bucket) in columns.iter_mut().zip(buckets) {
            column.push(bucket);
        }
        map_meters.push(meter);
    }

    // ---- reduce phase ---------------------------------------------------
    // Each reduce task merges its own spill buckets and runs as its own
    // threaded job on the same worker budget; outputs come back in task
    // order, so the concatenation below is byte-identical to the old
    // sequential driver loop.
    let reduce_results: Vec<(Vec<O>, TaskMeter)> = {
        let reducer = &reducer;
        let reduce_jobs: Vec<_> = columns
            .into_iter()
            .enumerate()
            .map(|(rid, column)| {
                let job = Arc::clone(&job);
                move || {
                    let start = Instant::now();
                    // Hash-grouped merge, in map-task order so per-key value
                    // order is deterministic. (A Hadoop-style sort-merge
                    // variant was tried and reverted: sorting flat pair
                    // vectors measured ~25% slower end-to-end than BTreeMap
                    // insertion here — §Perf log.)
                    let mut group: BTreeMap<M::K, Vec<M::V>> = BTreeMap::new();
                    let mut in_tuples = 0u64;
                    for bucket in column {
                        in_tuples += bucket.len() as u64;
                        for (k, v) in bucket {
                            group.entry(k).or_default().push(v);
                        }
                    }
                    let mut rc = Counters::new();
                    rc.add(keys::REDUCE_INPUT_TUPLES, in_tuples);
                    let mut outputs = Vec::new();
                    for (k, vs) in &group {
                        if let Some(o) = reducer.reduce(k, vs) {
                            outputs.push(o);
                        }
                    }
                    rc.add(keys::REDUCE_OUTPUT_RECORDS, outputs.len() as u64);
                    let meter = TaskMeter {
                        task_id: rid,
                        job,
                        counters: rc,
                        preferred_nodes: Vec::new(),
                        wall_secs: start.elapsed().as_secs_f64(),
                    };
                    (outputs, meter)
                }
            })
            .collect();
        pool::run_batch_scoped(workers, reduce_jobs)
    };

    let mut outputs = Vec::new();
    let mut reduce_meters = Vec::with_capacity(n_reducers);
    for (task_outputs, meter) in reduce_results {
        counters.merge(&meter.counters);
        outputs.extend(task_outputs);
        reduce_meters.push(meter);
    }

    crate::debug!(
        "job {job}: {} map + {} reduce tasks on {workers} workers, {} shuffled tuples, {:.3}s host",
        map_meters.len(),
        reduce_meters.len(),
        counters.get(keys::COMBINE_OUTPUT_TUPLES),
        job_start.elapsed().as_secs_f64(),
    );

    JobOutput { name, outputs, counters, map_meters, reduce_meters, aux, aux_divergence }
}

fn combine_pairs<K: Ord + Clone + std::hash::Hash, V, C: Combiner<K, V> + ?Sized>(
    combiner: &C,
    pairs: Vec<(K, V)>,
) -> Vec<(K, V)> {
    let mut grouped: HashMap<K, Vec<V>> = HashMap::with_capacity(pairs.len() / 2 + 1);
    for (k, v) in pairs {
        grouped.entry(k).or_default().push(v);
    }
    let mut out: Vec<(K, V)> = grouped
        .into_iter()
        .map(|(k, mut vs)| {
            let v = combiner.combine(&k, &mut vs);
            (k, v)
        })
        .collect();
    // Deterministic downstream order regardless of hash iteration.
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::TransactionDb;
    use crate::hdfs;
    use crate::itemset::Itemset;
    use crate::mapreduce::api::{HashPartitioner, MinSupportReducer, SumCombiner};

    /// Word-count analog: emit (item, 1) per item — the paper's Job1 mapper.
    struct ItemMapper;
    impl Mapper for ItemMapper {
        type K = u32;
        type V = u64;
        fn map(&mut self, _off: usize, record: &Itemset, ctx: &mut Context<u32, u64>) {
            for &i in record {
                ctx.write(i, 1);
            }
        }
    }

    fn splits_for(db: &TransactionDb, per_split: usize) -> Vec<InputSplit> {
        let f = hdfs::put(db, per_split, 4, 3, 1);
        hdfs::nline_splits(&f, per_split)
    }

    fn demo_db() -> TransactionDb {
        TransactionDb::new(
            "d",
            4,
            vec![vec![0, 1], vec![0, 2], vec![0, 1, 3], vec![1], vec![0]],
        )
    }

    fn run_wordcount(workers: usize, n_reducers: usize, min_count: u64) -> JobOutput<(u32, u64)> {
        let db = demo_db();
        run_job(JobSpec {
            name: "wc".into(),
            splits: splits_for(&db, 2),
            mapper_factory: Box::new(|_| ItemMapper),
            combiner: Some(Box::new(SumCombiner)),
            reducer: MinSupportReducer { min_count },
            partitioner: Box::new(HashPartitioner),
            n_reducers,
            workers,
        })
    }

    fn sorted(mut v: Vec<(u32, u64)>) -> Vec<(u32, u64)> {
        v.sort();
        v
    }

    #[test]
    fn wordcount_correct() {
        let out = run_wordcount(1, 2, 1);
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3), (2, 1), (3, 1)]);
    }

    #[test]
    fn min_support_filter_applies() {
        let out = run_wordcount(1, 2, 3);
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3)]);
    }

    #[test]
    fn parallel_equals_sequential() {
        // Threaded mappers AND threaded reducers must be invisible in the
        // output, across the workers × n_reducers grid.
        let baseline = sorted(run_wordcount(1, 1, 1).outputs);
        for workers in [1, 4] {
            for n_reducers in [1, 3] {
                let out = run_wordcount(workers, n_reducers, 1);
                assert_eq!(out.reduce_meters.len(), n_reducers);
                assert_eq!(
                    sorted(out.outputs),
                    baseline,
                    "workers={workers} n_reducers={n_reducers}"
                );
            }
        }
    }

    #[test]
    fn threaded_execution_is_deterministic() {
        // Not just the same multiset: byte-identical output ORDER, because
        // spills are pre-sorted and reduce outputs concatenate in task
        // order regardless of which worker thread ran them.
        let seq = run_wordcount(1, 3, 1).outputs;
        for _ in 0..5 {
            assert_eq!(run_wordcount(4, 3, 1).outputs, seq);
        }
    }

    #[test]
    fn counters_account_for_combine() {
        let out = run_wordcount(1, 1, 1);
        assert_eq!(out.counters.get(keys::MAP_INPUT_RECORDS), 5);
        assert_eq!(out.counters.get(keys::MAP_OUTPUT_TUPLES), 9); // raw item writes
        // 3 splits: {01,02}->(0:2,1:1,2:1)=3, {013,1}->(0:1,1:2,3:1)=3, {0}->1
        assert_eq!(out.counters.get(keys::COMBINE_OUTPUT_TUPLES), 7);
        assert_eq!(out.counters.get(keys::REDUCE_INPUT_TUPLES), 7);
        assert_eq!(out.counters.get(keys::REDUCE_OUTPUT_RECORDS), 4);
    }

    #[test]
    fn spill_partitions_metered() {
        // 3 map tasks spilling into 2 partitions each: at most 6 non-empty
        // buckets, at least one per non-empty task.
        let out = run_wordcount(1, 2, 1);
        let spills = out.counters.get(keys::SHUFFLE_SPILL_PARTITIONS);
        assert!((3..=6).contains(&spills), "spills {spills}");
        // Single reducer: exactly one bucket per task.
        let out = run_wordcount(1, 1, 1);
        assert_eq!(out.counters.get(keys::SHUFFLE_SPILL_PARTITIONS), 3);
    }

    #[test]
    fn task_meters_present() {
        let out = run_wordcount(1, 2, 1);
        assert_eq!(out.map_meters.len(), 3);
        assert_eq!(out.reduce_meters.len(), 2);
        assert!(out.map_meters.iter().all(|m| m.wall_secs >= 0.0));
        assert!(!out.map_meters[0].preferred_nodes.is_empty());
    }

    #[test]
    fn job_name_reaches_meters() {
        let out = run_wordcount(1, 2, 1);
        assert_eq!(out.name, "wc");
        assert!(out.map_meters.iter().all(|m| &*m.job == "wc"));
        assert!(out.reduce_meters.iter().all(|m| &*m.job == "wc"));
    }

    #[test]
    fn reducer_count_respected() {
        let out = run_wordcount(1, 4, 1);
        assert_eq!(out.reduce_meters.len(), 4);
        let total: u64 =
            out.reduce_meters.iter().map(|m| m.counters.get(keys::REDUCE_INPUT_TUPLES)).sum();
        assert_eq!(total, 7);
    }

    /// Mapper that reports through the aux side-channel.
    struct AuxMapper(u64);
    impl Mapper for AuxMapper {
        type K = u32;
        type V = u64;
        fn map(&mut self, _o: usize, _r: &Itemset, _c: &mut Context<u32, u64>) {}
        fn cleanup(&mut self, ctx: &mut Context<u32, u64>) {
            ctx.set_aux(keys::CANDIDATES, self.0);
        }
    }

    fn run_aux_job(factory: impl Fn(usize) -> AuxMapper + Send + Sync) -> JobOutput<(u32, u64)> {
        let db = demo_db();
        run_job(JobSpec {
            name: "aux".into(),
            splits: splits_for(&db, 2),
            mapper_factory: Box::new(factory),
            combiner: None,
            reducer: MinSupportReducer { min_count: 1 },
            partitioner: Box::new(HashPartitioner),
            n_reducers: 1,
            workers: 1,
        })
    }

    #[test]
    fn aux_takes_max_across_tasks() {
        let db = demo_db();
        let out = run_job(JobSpec {
            name: "aux".into(),
            splits: splits_for(&db, 2),
            mapper_factory: Box::new(|task| AuxMapper(10 + task as u64)),
            combiner: None,
            reducer: MinSupportReducer { min_count: 1 },
            partitioner: Box::new(HashPartitioner),
            n_reducers: 1,
            workers: 1,
        });
        assert_eq!(out.aux.get(keys::CANDIDATES), Some(&12)); // 3 tasks: 10,11,12
    }

    #[test]
    fn divergent_aux_values_are_detected() {
        // Per-task values 10,11,12: legal for a generic job, but flagged so
        // an Apriori driver (where all tasks must agree) can assert.
        let out = run_aux_job(|task| AuxMapper(10 + task as u64));
        assert_eq!(out.aux_divergence, vec![keys::CANDIDATES]);
    }

    #[test]
    fn agreeing_aux_values_are_not_flagged() {
        let out = run_aux_job(|_| AuxMapper(7));
        assert_eq!(out.aux.get(keys::CANDIDATES), Some(&7));
        assert!(out.aux_divergence.is_empty());
    }

    #[test]
    fn no_combiner_shuffles_raw_tuples() {
        let db = demo_db();
        let out = run_job(JobSpec {
            name: "raw".into(),
            splits: splits_for(&db, 2),
            mapper_factory: Box::new(|_| ItemMapper),
            combiner: None,
            reducer: MinSupportReducer { min_count: 1 },
            partitioner: Box::new(HashPartitioner),
            n_reducers: 2,
            workers: 1,
        });
        assert_eq!(out.counters.get(keys::COMBINE_OUTPUT_TUPLES), 9); // = raw
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3), (2, 1), (3, 1)]);
    }
}
