//! Engine data types ([`TaskMeter`], [`JobOutput`]) and the deprecated
//! one-shot compatibility layer ([`JobSpec`] / [`run_job`]).
//!
//! The execution engine itself lives in [`super::executor`] (Engine v2,
//! DESIGN.md §9): jobs are built with `JobBuilder`, submitted to an
//! `Executor` owning one persistent worker pool, and driven through a
//! `JobHandle`. The blocking free function [`run_job`] survives only as a
//! thin shim that submits the spec to a throwaway single-job `Executor` —
//! byte-identical output, but a fresh pool per call and no sharing across
//! concurrent jobs; migrate to the executor API.

use super::api::{Combiner, Mapper, Partitioner, Reducer};
use super::counters::Counters;
use super::executor::{Executor, JobBuilder};
use crate::hdfs::InputSplit;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Per-task measurement record consumed by the cluster scheduler.
#[derive(Debug, Clone)]
pub struct TaskMeter {
    /// Task index within its phase (map and reduce number independently).
    pub task_id: usize,
    /// Name of the job this task belongs to (phase attribution in reports).
    pub job: Arc<str>,
    /// The task's operation counters.
    pub counters: Counters,
    /// Locality hint from the task's input split (empty for reduce tasks).
    pub preferred_nodes: Vec<usize>,
    /// Real wall-clock seconds this task took on the host machine.
    pub wall_secs: f64,
}

/// Everything a finished job reports back to its driver.
#[derive(Debug)]
pub struct JobOutput<O> {
    /// The job name this output belongs to.
    pub name: String,
    /// Reduce outputs, concatenated in reduce-task order.
    pub outputs: Vec<O>,
    /// Merged counters across all map and reduce tasks.
    pub counters: Counters,
    /// One meter per map task.
    pub map_meters: Vec<TaskMeter>,
    /// One meter per reduce task.
    pub reduce_meters: Vec<TaskMeter>,
    /// Driver side-channel values (max across tasks — every map task of an
    /// Apriori job computes the same `candidateCount`/`npass`).
    pub aux: BTreeMap<&'static str, u64>,
    /// Aux keys whose values DIVERGED across map tasks (the max still
    /// wins, for backward compatibility). An Apriori driver treats any
    /// entry here as a bug — see the `debug_assert!` in
    /// `crate::coordinator::session` — but generic jobs may legally
    /// report per-task values.
    pub aux_divergence: Vec<&'static str>,
}

/// A configured job as one struct literal — the pre-executor API.
#[deprecated(
    since = "0.3.0",
    note = "build the job fluently with mapreduce::executor::JobBuilder and submit it to an Executor (DESIGN.md §9)"
)]
pub struct JobSpec<M: Mapper, R> {
    /// Job name (flows into meters and phase records).
    pub name: String,
    /// Input splits; one map task each.
    pub splits: Vec<InputSplit>,
    /// Builds the mapper instance for task `i` (Hadoop constructs one Mapper
    /// per split); runs on the task's thread.
    pub mapper_factory: Box<dyn Fn(usize) -> M + Send + Sync>,
    /// Optional map-side combiner.
    pub combiner: Option<Box<dyn Combiner<M::K, M::V>>>,
    /// The reduce function (shared read-only across tasks).
    pub reducer: R,
    /// Key -> reducer routing.
    pub partitioner: Box<dyn Partitioner<M::K>>,
    /// Number of reduce tasks (clamped to >= 1).
    pub n_reducers: usize,
    /// Host threads for real execution (not simulated slots!). Under the
    /// shim this sizes the throwaway per-call pool; the executor API sizes
    /// one shared pool instead.
    pub workers: usize,
}

/// Run one job to completion on a throwaway, single-job [`Executor`].
///
/// Deprecated shim over the executor API: output is byte-identical, but
/// every call pays for a fresh `workers`-thread pool and nothing bounds
/// concurrent callers collectively — the very oversubscription the shared
/// executor exists to prevent.
#[deprecated(
    since = "0.3.0",
    note = "submit through mapreduce::executor::Executor, which shares one bounded worker pool across jobs (DESIGN.md §9)"
)]
#[allow(deprecated)]
pub fn run_job<M, R, O>(spec: JobSpec<M, R>) -> JobOutput<O>
where
    M: Mapper + 'static,
    R: Reducer<M::K, M::V, Out = O> + 'static,
    M::K: 'static,
    M::V: 'static,
    O: Send + 'static,
{
    let JobSpec {
        name,
        splits,
        mapper_factory,
        combiner,
        reducer,
        partitioner,
        n_reducers,
        workers,
    } = spec;
    let mut job: JobBuilder<M::K, M::V, O> = JobBuilder::new(name)
        .splits(splits)
        .mapper(move |task| mapper_factory(task))
        .reducer(reducer)
        .boxed_partitioner(partitioner)
        .reducers(n_reducers);
    if let Some(combiner) = combiner {
        job = job.boxed_combiner(combiner);
    }
    Executor::new(workers)
        .submit(job)
        .wait()
        // lint:allow(unwrap-in-library): this deprecated shim builds the job
        // itself and attaches no cancel token, so Cancelled cannot occur.
        .expect("a JobSpec carries no cancel token, so the job cannot be cancelled")
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // this module exists to test the deprecated shim

    use super::*;
    use crate::dataset::TransactionDb;
    use crate::hdfs;
    use crate::itemset::Itemset;
    use crate::mapreduce::api::{Context, HashPartitioner, MinSupportReducer, SumCombiner};
    use crate::mapreduce::counters::keys;

    struct ItemMapper;
    impl Mapper for ItemMapper {
        type K = u32;
        type V = u64;
        fn map(&mut self, _off: usize, record: &Itemset, ctx: &mut Context<u32, u64>) {
            for &i in record {
                ctx.write(i, 1);
            }
        }
    }

    fn demo_db() -> TransactionDb {
        TransactionDb::new(
            "d",
            4,
            vec![vec![0, 1], vec![0, 2], vec![0, 1, 3], vec![1], vec![0]],
        )
    }

    fn run_shim(workers: usize, n_reducers: usize, min_count: u64) -> JobOutput<(u32, u64)> {
        let db = demo_db();
        let f = hdfs::put(&db, 2, 4, 3, 1);
        run_job(JobSpec {
            name: "wc".into(),
            splits: hdfs::nline_splits(&f, 2),
            mapper_factory: Box::new(|_| ItemMapper),
            combiner: Some(Box::new(SumCombiner)),
            reducer: MinSupportReducer { min_count },
            partitioner: Box::new(HashPartitioner),
            n_reducers,
            workers,
        })
    }

    #[test]
    fn shim_matches_the_executor_byte_for_byte() {
        // The deprecated blocking path must remain indistinguishable from
        // the executor it now wraps: same output order, counters, meters.
        for (workers, n_reducers) in [(1, 1), (1, 3), (4, 2)] {
            let shim = run_shim(workers, n_reducers, 1);
            let db = demo_db();
            let f = hdfs::put(&db, 2, 4, 3, 1);
            let exec = Executor::new(workers)
                .submit(
                    JobBuilder::new("wc")
                        .splits(hdfs::nline_splits(&f, 2))
                        .mapper(|_| ItemMapper)
                        .combiner(SumCombiner)
                        .reducer(MinSupportReducer { min_count: 1 })
                        .reducers(n_reducers),
                )
                .wait()
                .expect("no cancel token attached");
            assert_eq!(shim.outputs, exec.outputs, "workers={workers} reducers={n_reducers}");
            assert_eq!(shim.counters, exec.counters);
            assert_eq!(shim.map_meters.len(), exec.map_meters.len());
            assert_eq!(shim.reduce_meters.len(), exec.reduce_meters.len());
        }
    }

    #[test]
    fn shim_filters_and_counts_like_before() {
        let out = run_shim(1, 2, 3);
        let mut sorted = out.outputs.clone();
        sorted.sort();
        assert_eq!(sorted, vec![(0, 4), (1, 3)]);
        assert_eq!(out.counters.get(keys::MAP_INPUT_RECORDS), 5);
        assert_eq!(out.name, "wc");
        assert!(out.map_meters.iter().all(|m| &*m.job == "wc"));
    }
}
