//! A from-scratch MapReduce framework (the paper's substrate): the Hadoop-
//! style programming API ([`api`]), the execution engine ([`engine`]), and
//! the counter framework ([`counters`]).
//!
//! Input comes from [`crate::hdfs`] splits; timing comes from
//! [`crate::cluster`], which converts the engine's per-task meters into
//! simulated cluster seconds.

pub mod api;
pub mod counters;
pub mod engine;

pub use api::{
    Combiner, Context, HashPartitioner, Mapper, MinSupportReducer, Partitioner, Reducer,
    SumCombiner, SumReducer,
};
pub use counters::{keys, Counters};
pub use engine::{run_job, JobOutput, JobSpec, TaskMeter};
