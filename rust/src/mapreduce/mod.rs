//! A from-scratch MapReduce framework (the paper's substrate): the Hadoop-
//! style programming API ([`api`]), the executor-backed execution engine
//! ([`executor`], Engine v2 — one shared worker pool, `JobBuilder` /
//! `JobHandle` submission, task-granularity progress and in-job
//! cancellation), the engine data types and deprecated one-shot shim
//! ([`engine`]), and the counter framework ([`counters`]).
//!
//! Input comes from [`crate::hdfs`] splits; timing comes from
//! [`crate::cluster`], which converts the engine's per-task meters into
//! simulated cluster seconds.

pub mod api;
pub mod counters;
pub mod engine;
pub mod executor;

pub use api::{
    Combiner, Context, HashPartitioner, Mapper, MinSupportReducer, Partitioner, Reducer,
    SumCombiner, SumReducer,
};
pub use counters::{keys, Counters};
#[allow(deprecated)]
pub use engine::{run_job, JobSpec};
pub use engine::{JobOutput, TaskMeter};
pub use executor::{CancelToken, Executor, JobBuilder, JobError, JobHandle, TaskEvent, TaskKind};
