//! Job/task counters, mirroring Hadoop's counter framework. The cluster
//! cost model converts these *measured-work* counters into simulated time.

use std::collections::BTreeMap;

/// Well-known counter names used across the system.
pub mod keys {
    /// Records fed to map().
    pub const MAP_INPUT_RECORDS: &str = "map_input_records";
    /// Raw (key, value) writes from mappers (pre-combine).
    pub const MAP_OUTPUT_TUPLES: &str = "map_output_tuples";
    /// Tuples leaving the combine stage (what actually shuffles).
    pub const COMBINE_OUTPUT_TUPLES: &str = "combine_output_tuples";
    /// Non-empty per-reducer spill buckets written by map tasks (the number
    /// of map-side partition files a real Hadoop shuffle would fetch).
    pub const SHUFFLE_SPILL_PARTITIONS: &str = "shuffle_spill_partitions";
    /// Tuples received by reducers.
    pub const REDUCE_INPUT_TUPLES: &str = "reduce_input_tuples";
    /// Records written by reducers.
    pub const REDUCE_OUTPUT_RECORDS: &str = "reduce_output_records";
    /// apriori-gen/non-apriori-gen join pairs considered (per map() call,
    /// i.e. already multiplied by records for the faithful re-invocation).
    pub const JOIN_PAIRS: &str = "join_pairs";
    /// Prune subset-membership probes.
    pub const PRUNE_CHECKS: &str = "prune_checks";
    /// Candidate-trie insertions performed.
    pub const CANDS_BUILT: &str = "cands_built";
    /// Trie nodes visited during subset() counting.
    pub const SUBSET_VISITS: &str = "subset_visits";
    /// u64-word operations in the vertical TID-bitmap backend: one per word
    /// OR while building the per-item TID-lists, one per word AND+popcount
    /// while intersecting a candidate's rows.
    pub const BITMAP_WORD_OPS: &str = "bitmap_word_ops";
    /// O(1) increments of the dense triangular pair/item matrix (the fused
    /// pass-1/2 job and the `triangular` k=2 counting backend).
    pub const TRIANGLE_UPDATES: &str = "triangle_updates";
    /// Total item occurrences fed to map() (Σ transaction widths) — pure
    /// bookkeeping (no cost weight) feeding the dataset density profile the
    /// `auto` backend pick uses.
    pub const RECORD_ITEMS: &str = "record_items";
    /// Number of candidate itemsets counted in this job (driver bookkeeping).
    pub const CANDIDATES: &str = "candidates";
    /// Number of passes combined by the mapper (driver bookkeeping).
    pub const NPASS: &str = "npass";
}

/// A bag of named u64 counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Counters(BTreeMap<&'static str, u64>);

impl Counters {
    /// Empty counter bag.
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    /// Add `delta` to `name` (creating it at 0).
    pub fn add(&mut self, name: &'static str, delta: u64) {
        *self.0.entry(name).or_insert(0) += delta;
    }

    /// Overwrite `name` with `value`.
    pub fn set(&mut self, name: &'static str, value: u64) {
        self.0.insert(name, value);
    }

    /// Read `name` (0 when absent).
    pub fn get(&self, name: &str) -> u64 {
        self.0.get(name).copied().unwrap_or(0)
    }

    /// Add every counter of `other` into `self`.
    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.0 {
            *self.0.entry(k).or_insert(0) += v;
        }
    }

    /// Iterate `(name, value)` pairs in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.0.iter().map(|(&k, &v)| (k, v))
    }

    /// Whether no counter was ever touched.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

impl std::fmt::Display for Counters {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, (k, v)) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{k}={v}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_get_set() {
        let mut c = Counters::new();
        c.add(keys::MAP_INPUT_RECORDS, 5);
        c.add(keys::MAP_INPUT_RECORDS, 3);
        assert_eq!(c.get(keys::MAP_INPUT_RECORDS), 8);
        assert_eq!(c.get("missing"), 0);
        c.set(keys::NPASS, 4);
        assert_eq!(c.get(keys::NPASS), 4);
    }

    #[test]
    fn merge_sums() {
        let mut a = Counters::new();
        a.add("x", 1);
        let mut b = Counters::new();
        b.add("x", 2);
        b.add("y", 7);
        a.merge(&b);
        assert_eq!(a.get("x"), 3);
        assert_eq!(a.get("y"), 7);
    }

    #[test]
    fn display_lists_counters() {
        let mut c = Counters::new();
        c.add("a", 1);
        c.add("b", 2);
        assert_eq!(c.to_string(), "a=1, b=2");
    }
}
