//! The MapReduce programming interface: `Mapper`, `Combiner`, `Reducer`,
//! `Partitioner` traits and the task `Context`, mirroring the Hadoop API the
//! paper's pseudocode is written against (Algorithms 1–5).

use super::counters::{keys, Counters};
use crate::itemset::Itemset;
use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};

/// Per-task context handed to mappers: output collection + counters + the
/// "job configuration context" side-channel the paper's mappers use to send
/// `candidateCount` / `npass` back to the driver.
pub struct Context<K, V> {
    out: Vec<(K, V)>,
    /// Task-local operation counters (feed the cluster cost model).
    pub counters: Counters,
    /// Driver side-channel (`set the value of X to context`, Algs 3–5).
    pub aux: BTreeMap<&'static str, u64>,
}

impl<K, V> Context<K, V> {
    /// Fresh context with empty output, counters, and aux channel.
    pub fn new() -> Self {
        Self { out: Vec::new(), counters: Counters::new(), aux: BTreeMap::new() }
    }

    /// `write(key, value)` of the Hadoop API.
    #[inline]
    pub fn write(&mut self, key: K, value: V) {
        self.counters.add(keys::MAP_OUTPUT_TUPLES, 1);
        self.out.push((key, value));
    }

    /// Record an output tuple that was already locally aggregated (in-mapper
    /// combining): counts `raw` raw writes but emits a single tuple.
    #[inline]
    pub fn write_combined(&mut self, key: K, value: V, raw: u64) {
        self.counters.add(keys::MAP_OUTPUT_TUPLES, raw);
        self.out.push((key, value));
    }

    /// Send a driver value through the job-configuration side-channel.
    pub fn set_aux(&mut self, name: &'static str, value: u64) {
        self.aux.insert(name, value);
    }

    /// Drain the collected (key, value) output.
    pub fn take_output(&mut self) -> Vec<(K, V)> {
        std::mem::take(&mut self.out)
    }

    /// Number of buffered output tuples.
    pub fn output_len(&self) -> usize {
        self.out.len()
    }
}

impl<K, V> Default for Context<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

/// A map task body. One instance per task (per input split); `map` is called
/// once per record; `cleanup` runs after the last record (Hadoop semantics).
pub trait Mapper: Send {
    /// Output key type.
    type K: Send + Clone + Ord + Hash;
    /// Output value type.
    type V: Send + Clone;

    /// Process one record at byte-offset-like key `offset`.
    fn map(&mut self, offset: usize, record: &Itemset, ctx: &mut Context<Self::K, Self::V>);

    /// Runs after the last record of the split (Hadoop's `cleanup`).
    fn cleanup(&mut self, _ctx: &mut Context<Self::K, Self::V>) {}
}

/// Combiner: folds the values of one key locally on the map side.
/// `ItemsetCombiner` of the paper = [`SumCombiner`].
pub trait Combiner<K, V>: Send + Sync {
    /// Fold `values` of one `key` into a single value.
    fn combine(&self, key: &K, values: &mut Vec<V>) -> V;
}

/// Reducer: folds the values of one key globally; `None` drops the key
/// (how `ItemsetReducer` applies the min-support filter).
pub trait Reducer<K, V>: Send + Sync {
    /// Reduce output record type.
    type Out: Send;
    /// Fold all `values` of `key`; `None` drops the key.
    fn reduce(&self, key: &K, values: &[V]) -> Option<Self::Out>;
}

/// Partitioner: key -> reducer index. Default is hash partitioning.
pub trait Partitioner<K>: Send + Sync {
    /// Reducer index for `key`, in `[0, n_reducers)`.
    fn partition(&self, key: &K, n_reducers: usize) -> usize;
}

/// Hash partitioner over the key's `Hash` impl, routed through the crate's
/// pinned zero-key SipHash-1-3 ([`crate::util::siphash::SipHasher13`]).
///
/// It used to use `std::collections::hash_map::DefaultHasher`, whose
/// algorithm the standard library explicitly leaves unspecified across
/// releases: a toolchain bump could silently re-route every key to a
/// different reducer, perturbing stored segment outputs, reduce-task
/// workload splits, and the simulated timings derived from them. The
/// explicit fixed-key hasher makes partition placement a specified,
/// toolchain-independent property (pinned-vector test below).
pub struct HashPartitioner;

impl<K: Hash> Partitioner<K> for HashPartitioner {
    fn partition(&self, key: &K, n_reducers: usize) -> usize {
        let mut h = crate::util::siphash::SipHasher13::new();
        key.hash(&mut h);
        (h.finish() % n_reducers as u64) as usize
    }
}

/// The paper's `ItemsetCombiner`: sums local counts.
pub struct SumCombiner;

impl<K: Send + Sync> Combiner<K, u64> for SumCombiner {
    fn combine(&self, _key: &K, values: &mut Vec<u64>) -> u64 {
        values.drain(..).sum()
    }
}

/// The paper's `ItemsetReducer`: sums counts, keeps keys meeting
/// `min_count` (Algorithm 1).
pub struct MinSupportReducer {
    /// Keys whose summed count falls below this are dropped.
    pub min_count: u64,
}

impl<K: Send + Sync + Clone> Reducer<K, u64> for MinSupportReducer {
    type Out = (K, u64);
    fn reduce(&self, key: &K, values: &[u64]) -> Option<(K, u64)> {
        let sum: u64 = values.iter().sum();
        (sum >= self.min_count).then(|| (key.clone(), sum))
    }
}

/// Pass-through reducer that sums without filtering (for tests/aggregations).
pub struct SumReducer;

impl<K: Send + Sync + Clone> Reducer<K, u64> for SumReducer {
    type Out = (K, u64);
    fn reduce(&self, key: &K, values: &[u64]) -> Option<(K, u64)> {
        Some((key.clone(), values.iter().sum()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_write_counts() {
        let mut ctx: Context<u32, u64> = Context::new();
        ctx.write(1, 1);
        ctx.write(2, 1);
        ctx.write_combined(3, 10, 10);
        assert_eq!(ctx.counters.get(keys::MAP_OUTPUT_TUPLES), 12);
        assert_eq!(ctx.output_len(), 3);
        let out = ctx.take_output();
        assert_eq!(out.len(), 3);
        assert_eq!(ctx.output_len(), 0);
    }

    #[test]
    fn sum_combiner_folds() {
        let c = SumCombiner;
        let mut vals = vec![1u64, 2, 3];
        assert_eq!(Combiner::<u32, u64>::combine(&c, &0, &mut vals), 6);
    }

    #[test]
    fn min_support_reducer_filters() {
        let r = MinSupportReducer { min_count: 3 };
        assert_eq!(r.reduce(&7u32, &[1, 1]), None);
        assert_eq!(r.reduce(&7u32, &[1, 2]), Some((7, 3)));
    }

    #[test]
    fn hash_partitioner_stable_and_in_range() {
        let p = HashPartitioner;
        for key in 0u32..100 {
            let a = p.partition(&key, 7);
            let b = p.partition(&key, 7);
            assert_eq!(a, b);
            assert!(a < 7);
        }
    }

    #[test]
    fn partitions_spread_keys() {
        let p = HashPartitioner;
        let mut seen = vec![false; 4];
        for key in 0u32..64 {
            seen[p.partition(&key, 4)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    /// Pinned partition assignments. These values are frozen: stored
    /// segment outputs and simulated per-task timings depend on partition
    /// routing, so a change here means the partitioner stopped being
    /// deterministic across toolchains — the exact regression this pin
    /// exists to catch. The `u32` vectors are endianness-independent (the
    /// hasher pins integer writes to LE); the itemset vectors additionally
    /// assume std's native-endian integer `hash_slice` byte stream
    /// (`len as u64 (LE)` then the elements' raw LE bytes), hence the
    /// little-endian gate — every supported host is little-endian.
    #[test]
    #[cfg_attr(target_endian = "big", ignore = "itemset stream pins std's LE hash_slice bytes")]
    fn pinned_partition_vectors() {
        let p = HashPartitioner;
        for (key, expect) in
            [(0u32, 3usize), (1, 5), (2, 5), (3, 1), (42, 4), (191, 5), (u32::MAX, 6)]
        {
            assert_eq!(p.partition(&key, 7), expect, "u32 key {key}");
        }
        let itemsets: [(&[u32], usize); 7] = [
            (&[0], 0),
            (&[1], 5),
            (&[5], 6),
            (&[0, 1], 2),
            (&[1, 2, 3], 1),
            (&[2, 7, 19, 40], 2),
            (&[10, 20, 30, 40, 50, 60], 2),
        ];
        for (items, expect) in itemsets {
            let key: crate::itemset::Itemset = items.to_vec();
            assert_eq!(p.partition(&key, 7), expect, "itemset key {key:?}");
        }
    }

    #[test]
    fn prop_hash_partitioner_stable_and_in_range() {
        // Property: over arbitrary itemset keys (what the Apriori jobs
        // actually shuffle), the partition is in-range and repeat calls
        // agree — the map-side spill routing depends on both.
        use crate::util::check::{forall, ItemsetGen};
        let gen = ItemsetGen { universe: 500, max_len: 12 };
        forall(91, 300, &gen, |key| {
            let p = HashPartitioner;
            [1usize, 2, 3, 7, 16]
                .iter()
                .all(|&n| p.partition(key, n) < n && p.partition(key, n) == p.partition(key, n))
        });
    }
}
