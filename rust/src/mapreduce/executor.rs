//! The executor-backed job submission API (Engine v2, DESIGN.md §9).
//!
//! Hadoop drivers do not *run* jobs — they configure a `Job`, submit it to
//! a shared cluster through a `JobClient`, and watch its progress. This
//! module is that shape for the simulator's host execution:
//!
//! * [`Executor`] owns ONE persistent [`WorkerPool`] sized once. Every job
//!   submitted to it — from any number of concurrent mining queries —
//!   executes its map and reduce tasks on that fixed thread set, so N
//!   simultaneous queries share one bounded host budget instead of each
//!   spawning its own `workers`-sized batch.
//! * [`JobBuilder`] replaces the struct-literal `JobSpec`: name, splits,
//!   mapper factory, optional combiner, reducer, partitioner and reducer
//!   count, with defaults ([`HashPartitioner`], one reducer) and
//!   type-erased `dyn` stages so drivers no longer thread three generic
//!   parameters around.
//! * [`JobHandle`] is returned by [`Executor::submit`] once the job's map
//!   tasks are enqueued: [`JobHandle::wait`] completes the job, and
//!   [`JobHandle::wait_with`] additionally streams task-granularity
//!   [`TaskEvent`]s (map/reduce task started/finished) to the caller.
//!   Cooperative cancellation via a [`CancelToken`] is checked *between
//!   tasks inside the running job*: tasks not yet started are skipped and
//!   the job returns [`JobError::Cancelled`].
//!
//! Execution semantics — spill format, combiner placement, counters,
//! [`TaskMeter`]s, aux-divergence detection, and byte-level output order —
//! are identical to the retired in-place engine: task bodies are the same
//! code, results are collected by task index, and reduce outputs
//! concatenate in task order. The cluster simulator cannot tell the
//! difference.

use super::api::{Combiner, Context, HashPartitioner, Mapper, Partitioner, Reducer};
use super::counters::{keys, Counters};
use super::engine::{JobOutput, TaskMeter};
use crate::hdfs::InputSplit;
use crate::util::pool::WorkerPool;
use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Cancellation
// ---------------------------------------------------------------------------

/// Cooperative cancellation flag. Inside a running job it is checked
/// between tasks (a started task always completes); the session layer
/// additionally checks it between MapReduce phases. Cloning shares the
/// flag.
#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation: tasks not yet started are skipped and the
    /// owning job (or mining run) reports itself cancelled.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    /// Whether cancellation has been requested.
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

// ---------------------------------------------------------------------------
// Errors and events
// ---------------------------------------------------------------------------

/// How a submitted job can fail. (Task panics are not errors — they
/// propagate to the waiting driver exactly like the in-place engine did.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobError {
    /// The job's [`CancelToken`] fired while tasks were still pending; the
    /// skipped tasks make the output unusable, so no [`JobOutput`] exists.
    Cancelled,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Cancelled => write!(f, "job cancelled before all tasks ran"),
        }
    }
}

impl std::error::Error for JobError {}

/// Which phase of a job a task belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// A map task (one per input split).
    Map,
    /// A reduce task (one per configured reducer).
    Reduce,
}

impl std::fmt::Display for TaskKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TaskKind::Map => "map",
            TaskKind::Reduce => "reduce",
        })
    }
}

/// Task-granularity progress of a running job, streamed to
/// [`JobHandle::wait_with`] in true execution order (a task's `Started`
/// always precedes its `Finished`; tasks from the same phase interleave
/// freely). The session layer forwards these into its `PhaseEvent` stream.
#[derive(Debug, Clone)]
pub enum TaskEvent {
    /// A worker began executing the task.
    Started {
        /// Name of the job the task belongs to.
        job: Arc<str>,
        /// Map or reduce.
        kind: TaskKind,
        /// Task index within its phase.
        task: usize,
        /// Total tasks in that phase.
        of: usize,
    },
    /// The task ran to completion.
    Finished {
        /// Name of the job the task belongs to.
        job: Arc<str>,
        /// Map or reduce.
        kind: TaskKind,
        /// Task index within its phase.
        task: usize,
        /// Total tasks in that phase.
        of: usize,
    },
}

// ---------------------------------------------------------------------------
// JobBuilder
// ---------------------------------------------------------------------------

/// The type-erased mapper constructor: one fresh mapper per task index.
type DynMapperFactory<K, V> = dyn Fn(usize) -> Box<dyn Mapper<K = K, V = V>> + Send + Sync;

/// A configured MapReduce job, built fluently and submitted to an
/// [`Executor`]. Mirrors Hadoop's `Job` object the way the retired
/// `JobSpec` struct literal did, but with defaults and `dyn`-erased stages:
///
/// ```no_run
/// # use mrapriori::mapreduce::executor::{Executor, JobBuilder};
/// # use mrapriori::mapreduce::api::{MinSupportReducer, SumCombiner};
/// # use mrapriori::coordinator::mappers::OneItemsetMapper;
/// # let splits = Vec::new();
/// let executor = Executor::new(4);
/// let out = executor
///     .submit(
///         JobBuilder::new("job1")
///             .splits(splits)
///             .mapper(|_task| OneItemsetMapper)
///             .combiner(SumCombiner)
///             .reducer(MinSupportReducer { min_count: 3 })
///             .reducers(4),
///     )
///     .wait()
///     .expect("no cancel token was attached");
/// # let _ = out.outputs;
/// ```
///
/// `mapper` and `reducer` are mandatory; [`Executor::submit`] panics with
/// the job's name if either is missing (a driver bug, not a runtime
/// condition). The partitioner defaults to [`HashPartitioner`] and the
/// reducer count to 1.
pub struct JobBuilder<K, V, O> {
    name: String,
    splits: Vec<InputSplit>,
    mapper_factory: Option<Arc<DynMapperFactory<K, V>>>,
    combiner: Option<Arc<dyn Combiner<K, V>>>,
    reducer: Option<Arc<dyn Reducer<K, V, Out = O>>>,
    partitioner: Arc<dyn Partitioner<K>>,
    n_reducers: usize,
    cancel: Option<CancelToken>,
}

impl<K, V, O> JobBuilder<K, V, O>
where
    K: Send + Clone + Ord + Hash + 'static,
    V: Send + Clone + 'static,
    O: Send + 'static,
{
    /// Start configuring a job. The name flows into task meters, the
    /// job output, and progress events.
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            splits: Vec::new(),
            mapper_factory: None,
            combiner: None,
            reducer: None,
            partitioner: Arc::new(HashPartitioner),
            n_reducers: 1,
            cancel: None,
        }
    }

    /// Input splits; one map task each.
    pub fn splits(mut self, splits: Vec<InputSplit>) -> Self {
        self.splits = splits;
        self
    }

    /// Mapper factory: builds the mapper instance for task `i` (Hadoop
    /// constructs one Mapper per split); runs on the task's worker thread.
    pub fn mapper<M, F>(mut self, factory: F) -> Self
    where
        M: Mapper<K = K, V = V> + 'static,
        F: Fn(usize) -> M + Send + Sync + 'static,
    {
        self.mapper_factory =
            Some(Arc::new(move |task| Box::new(factory(task)) as Box<dyn Mapper<K = K, V = V>>));
        self
    }

    /// Optional map-side combiner.
    pub fn combiner(mut self, combiner: impl Combiner<K, V> + 'static) -> Self {
        self.combiner = Some(Arc::new(combiner));
        self
    }

    /// Type-erased variant of [`JobBuilder::combiner`] for callers that
    /// already hold a boxed stage (e.g. the deprecated `JobSpec` shim).
    pub fn boxed_combiner(mut self, combiner: Box<dyn Combiner<K, V>>) -> Self {
        self.combiner = Some(Arc::from(combiner));
        self
    }

    /// The reduce function (shared read-only across reduce tasks).
    pub fn reducer(mut self, reducer: impl Reducer<K, V, Out = O> + 'static) -> Self {
        self.reducer = Some(Arc::new(reducer));
        self
    }

    /// Key → reducer routing; defaults to [`HashPartitioner`].
    pub fn partitioner(mut self, partitioner: impl Partitioner<K> + 'static) -> Self {
        self.partitioner = Arc::new(partitioner);
        self
    }

    /// Type-erased variant of [`JobBuilder::partitioner`].
    pub fn boxed_partitioner(mut self, partitioner: Box<dyn Partitioner<K>>) -> Self {
        self.partitioner = Arc::from(partitioner);
        self
    }

    /// Number of reduce tasks (clamped to ≥ 1 at submit).
    pub fn reducers(mut self, n_reducers: usize) -> Self {
        self.n_reducers = n_reducers;
        self
    }

    /// Attach a cancellation token: tasks check it before starting, so the
    /// token cancels the job *mid-flight* at task granularity.
    pub fn cancel_token(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }
}

// ---------------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------------

/// A job-submission service over one persistent, bounded worker pool.
///
/// Sized once (per process or per mining session); cloning shares the
/// pool, which is how one `Executor` serves many concurrent submitters.
/// All host-thread consumption of every submitted job is bounded by
/// [`Executor::workers`], observable via [`Executor::high_water_mark`].
#[derive(Clone)]
pub struct Executor {
    pool: Arc<WorkerPool>,
}

impl Executor {
    /// Spawn an executor with `workers.max(1)` pool threads.
    pub fn new(workers: usize) -> Self {
        Self { pool: Arc::new(WorkerPool::new(workers)) }
    }

    /// Size of the shared worker pool.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Maximum number of tasks that ever executed concurrently on this
    /// executor's pool — the oversubscription instrument (never exceeds
    /// [`Executor::workers`] by construction).
    pub fn high_water_mark(&self) -> usize {
        self.pool.high_water_mark()
    }

    /// Submit a job: its map tasks are enqueued immediately and start
    /// executing on the shared pool; the returned [`JobHandle`] completes
    /// the job.
    ///
    /// Panics if the builder lacks a mapper or reducer (driver bug).
    pub fn submit<K, V, O>(&self, job: JobBuilder<K, V, O>) -> JobHandle<O>
    where
        K: Send + Clone + Ord + Hash + 'static,
        V: Send + Clone + 'static,
        O: Send + 'static,
    {
        let JobBuilder {
            name,
            splits,
            mapper_factory,
            combiner,
            reducer,
            partitioner,
            n_reducers,
            cancel,
        } = job;
        let mapper_factory = mapper_factory
            .unwrap_or_else(|| panic!("job {name:?} submitted without a mapper"));
        let reducer =
            reducer.unwrap_or_else(|| panic!("job {name:?} submitted without a reducer"));
        let n_reducers = n_reducers.max(1);
        let job_name: Arc<str> = Arc::from(name.as_str());
        let cancel = cancel.unwrap_or_default();
        let abort = CancelToken::new();
        // lint:allow(wall-clock-in-sim): host-side meter for the job
        // report's wall seconds, not simulated time (DESIGN.md §2).
        let job_start = Instant::now();

        // ---- enqueue the map (+ combine + partition) phase ----------------
        let n_maps = splits.len();
        let (tx, map_rx) = mpsc::channel();
        for (task_id, split) in splits.into_iter().enumerate() {
            let tx = tx.clone();
            let cancel = cancel.clone();
            let abort = abort.clone();
            let factory = Arc::clone(&mapper_factory);
            let combiner = combiner.clone();
            let partitioner = Arc::clone(&partitioner);
            let job = Arc::clone(&job_name);
            self.pool.spawn(move || {
                // The in-job cancellation point: a task checks before it
                // starts; a started task always completes.
                if cancel.is_cancelled() || abort.is_cancelled() {
                    let _ = tx.send(TaskMsg::Skipped);
                    return;
                }
                let _ = tx.send(TaskMsg::Started(task_id));
                let run = || {
                    run_map_task(
                        task_id,
                        &split,
                        &*factory,
                        combiner.as_deref(),
                        &*partitioner,
                        n_reducers,
                        &job,
                    )
                };
                // Forward panics to the waiting driver instead of killing
                // the shared worker thread.
                match catch_unwind(AssertUnwindSafe(run)) {
                    Ok(result) => {
                        let _ = tx.send(TaskMsg::Finished(task_id, Box::new(result)));
                    }
                    Err(payload) => {
                        let _ = tx.send(TaskMsg::Panicked(payload));
                    }
                }
            });
        }

        JobHandle {
            name: Arc::clone(&job_name),
            cancel: cancel.clone(),
            abort: abort.clone(),
            inner: Some(Box::new(PendingJob {
                pool: Arc::clone(&self.pool),
                spec_name: name,
                job_name,
                n_maps,
                n_reducers,
                reducer,
                cancel,
                abort,
                map_rx,
                job_start,
            })),
        }
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("workers", &self.pool.workers())
            .field("high_water_mark", &self.pool.high_water_mark())
            .finish()
    }
}

// ---------------------------------------------------------------------------
// JobHandle
// ---------------------------------------------------------------------------

/// A submitted job: its map tasks are already queued on the executor's
/// pool. [`JobHandle::wait`] (or [`JobHandle::wait_with`]) completes the
/// job and returns its [`JobOutput`]. Dropping the handle without waiting
/// aborts the job best-effort: tasks not yet started are skipped.
pub struct JobHandle<O> {
    name: Arc<str>,
    cancel: CancelToken,
    abort: CancelToken,
    inner: Option<Box<dyn Pending<O>>>,
}

impl<O> JobHandle<O> {
    /// The job's name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Request cooperative cancellation through the job's token (the one
    /// attached via [`JobBuilder::cancel_token`], or the job's own if none
    /// was attached — note an attached token may be shared with the whole
    /// mining run).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clone of the job's cancellation token.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Drive the job to completion and return its output. A map or reduce
    /// task that panicked re-raises the panic here, on the driver thread.
    pub fn wait(self) -> Result<JobOutput<O>, JobError> {
        self.wait_with(|_| {})
    }

    /// Like [`JobHandle::wait`], streaming task-granularity progress
    /// events to `on_event` (invoked on this thread, in execution order).
    pub fn wait_with(
        mut self,
        mut on_event: impl FnMut(TaskEvent),
    ) -> Result<JobOutput<O>, JobError> {
        let inner = self.inner.take().expect("a job is waited on at most once");
        inner.wait(&mut on_event)
    }
}

impl<O> Drop for JobHandle<O> {
    fn drop(&mut self) {
        if self.inner.is_some() {
            // Dropped without waiting: skip whatever has not started yet
            // rather than mining into the void.
            self.abort.cancel();
        }
    }
}

impl<O> std::fmt::Debug for JobHandle<O> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle")
            .field("job", &self.name)
            .field("cancelled", &self.cancel.is_cancelled())
            .finish()
    }
}

// ---------------------------------------------------------------------------
// The driving side: drain map results, transpose, run reduce, assemble.
// ---------------------------------------------------------------------------

/// What a worker reports back per task.
enum TaskMsg<T> {
    /// The worker began executing task `i`.
    Started(usize),
    /// Task `i` completed with this result.
    Finished(usize, Box<T>),
    /// The task observed cancellation and never ran.
    Skipped,
    /// The task panicked; the payload re-raises on the driver.
    Panicked(Box<dyn std::any::Any + Send + 'static>),
}

/// Object-safe continuation of a submitted job (erases K and V so
/// [`JobHandle`] is generic only in the output type).
trait Pending<O>: Send {
    fn wait(
        self: Box<Self>,
        on_event: &mut dyn FnMut(TaskEvent),
    ) -> Result<JobOutput<O>, JobError>;
}

struct PendingJob<K, V, O> {
    pool: Arc<WorkerPool>,
    /// Original `String` name, returned in [`JobOutput::name`].
    spec_name: String,
    /// Shared name for meters and events.
    job_name: Arc<str>,
    n_maps: usize,
    n_reducers: usize,
    reducer: Arc<dyn Reducer<K, V, Out = O>>,
    cancel: CancelToken,
    abort: CancelToken,
    map_rx: mpsc::Receiver<TaskMsg<MapTaskResult<K, V>>>,
    job_start: Instant,
}

struct MapTaskResult<K, V> {
    meter: TaskMeter,
    /// One pre-combined, pre-sorted spill bucket per reducer.
    buckets: Vec<Vec<(K, V)>>,
    aux: BTreeMap<&'static str, u64>,
}

/// Drain one phase's channel: deliver events, place results by task index.
/// Returns `true` if any task was skipped due to cancellation.
fn drain_phase<T>(
    rx: &mpsc::Receiver<TaskMsg<T>>,
    n_tasks: usize,
    kind: TaskKind,
    job: &Arc<str>,
    on_event: &mut dyn FnMut(TaskEvent),
    slots: &mut [Option<T>],
) -> bool {
    let mut pending = n_tasks;
    let mut skipped = false;
    while pending > 0 {
        let msg = rx.recv().expect("a task worker died without reporting");
        match msg {
            TaskMsg::Started(task) => on_event(TaskEvent::Started {
                job: Arc::clone(job),
                kind,
                task,
                of: n_tasks,
            }),
            TaskMsg::Finished(task, result) => {
                slots[task] = Some(*result);
                pending -= 1;
                on_event(TaskEvent::Finished { job: Arc::clone(job), kind, task, of: n_tasks });
            }
            TaskMsg::Skipped => {
                skipped = true;
                pending -= 1;
            }
            TaskMsg::Panicked(payload) => resume_unwind(payload),
        }
    }
    skipped
}

/// Cancels the job's private abort token when dropped. Armed for the whole
/// of [`PendingJob::wait`], it guarantees that EVERY exit — success,
/// cancellation, a task panic re-raised by `drain_phase`, or a panic
/// unwinding out of the caller's event callback — leaves no queued task of
/// a job nobody will collect burning the shared pool. (On success all
/// tasks already ran, so the cancel is a no-op; `JobHandle::drop` cannot
/// cover these paths because `inner` was taken by `wait_with`.)
struct AbortOnExit(CancelToken);

impl Drop for AbortOnExit {
    fn drop(&mut self) {
        self.0.cancel();
    }
}

impl<K, V, O> Pending<O> for PendingJob<K, V, O>
where
    K: Send + Clone + Ord + Hash + 'static,
    V: Send + Clone + 'static,
    O: Send + 'static,
{
    fn wait(
        self: Box<Self>,
        on_event: &mut dyn FnMut(TaskEvent),
    ) -> Result<JobOutput<O>, JobError> {
        let PendingJob {
            pool,
            spec_name,
            job_name,
            n_maps,
            n_reducers,
            reducer,
            cancel,
            abort,
            map_rx,
            job_start,
        } = *self;
        // Abort the job's queued tasks on ANY exit from this function —
        // see [`AbortOnExit`]. Harmless on success (nothing left to skip).
        let _abort_on_exit = AbortOnExit(abort.clone());

        // ---- drain the map phase ------------------------------------------
        let mut map_slots: Vec<Option<MapTaskResult<K, V>>> = (0..n_maps).map(|_| None).collect();
        let skipped =
            drain_phase(&map_rx, n_maps, TaskKind::Map, &job_name, on_event, &mut map_slots);
        if skipped || cancel.is_cancelled() {
            // Either some map output is missing, or queueing the reduce
            // phase would be pointless (its tasks would all skip).
            return Err(JobError::Cancelled);
        }

        // ---- aggregate map side -------------------------------------------
        let mut counters = Counters::new();
        let mut aux: BTreeMap<&'static str, u64> = BTreeMap::new();
        let mut aux_divergence: Vec<&'static str> = Vec::new();
        let mut map_meters = Vec::with_capacity(n_maps);
        // Transpose the task-major spills into reducer-major columns. This
        // is the ONLY serial work between the two threaded phases — a Vec
        // move per (task, reducer) pair; the per-key grouping happens
        // inside each reduce task below.
        let mut columns: Vec<Vec<Vec<(K, V)>>> =
            (0..n_reducers).map(|_| Vec::with_capacity(n_maps)).collect();
        for slot in map_slots {
            let MapTaskResult { meter, buckets, aux: task_aux } =
                slot.expect("all map tasks reported");
            counters.merge(&meter.counters);
            for (k, v) in task_aux {
                if let Some(prev) = aux.get(k) {
                    if *prev != v && !aux_divergence.contains(&k) {
                        aux_divergence.push(k);
                    }
                }
                let entry = aux.entry(k).or_insert(0);
                *entry = (*entry).max(v);
            }
            for (column, bucket) in columns.iter_mut().zip(buckets) {
                column.push(bucket);
            }
            map_meters.push(meter);
        }

        // ---- enqueue + drain the reduce phase -----------------------------
        // Each reduce task merges its own column of spill buckets on the
        // shared pool; outputs come back indexed by task id, so the
        // concatenation below is byte-identical to a sequential driver loop.
        let (tx, reduce_rx) = mpsc::channel();
        for (task_id, column) in columns.into_iter().enumerate() {
            let tx = tx.clone();
            let cancel = cancel.clone();
            let abort = abort.clone();
            let reducer = Arc::clone(&reducer);
            let job = Arc::clone(&job_name);
            pool.spawn(move || {
                if cancel.is_cancelled() || abort.is_cancelled() {
                    let _ = tx.send(TaskMsg::Skipped);
                    return;
                }
                let _ = tx.send(TaskMsg::Started(task_id));
                let run = || run_reduce_task(task_id, column, &*reducer, Arc::clone(&job));
                match catch_unwind(AssertUnwindSafe(run)) {
                    Ok(result) => {
                        let _ = tx.send(TaskMsg::Finished(task_id, Box::new(result)));
                    }
                    Err(payload) => {
                        let _ = tx.send(TaskMsg::Panicked(payload));
                    }
                }
            });
        }
        drop(tx);
        let mut reduce_slots: Vec<Option<(Vec<O>, TaskMeter)>> =
            (0..n_reducers).map(|_| None).collect();
        let skipped = drain_phase(
            &reduce_rx,
            n_reducers,
            TaskKind::Reduce,
            &job_name,
            on_event,
            &mut reduce_slots,
        );
        if skipped {
            return Err(JobError::Cancelled);
        }

        // ---- assemble -----------------------------------------------------
        let mut outputs = Vec::new();
        let mut reduce_meters = Vec::with_capacity(n_reducers);
        for slot in reduce_slots {
            let (task_outputs, meter) = slot.expect("all reduce tasks reported");
            counters.merge(&meter.counters);
            outputs.extend(task_outputs);
            reduce_meters.push(meter);
        }

        crate::debug!(
            "job {job_name}: {} map + {} reduce tasks on {} pool workers, {} shuffled tuples, {:.3}s host",
            map_meters.len(),
            reduce_meters.len(),
            pool.workers(),
            counters.get(keys::COMBINE_OUTPUT_TUPLES),
            job_start.elapsed().as_secs_f64(),
        );

        Ok(JobOutput {
            name: spec_name,
            outputs,
            counters,
            map_meters,
            reduce_meters,
            aux,
            aux_divergence,
        })
    }
}

// ---------------------------------------------------------------------------
// Task bodies (identical computation to the retired in-place engine)
// ---------------------------------------------------------------------------

fn run_map_task<K, V>(
    task_id: usize,
    split: &InputSplit,
    factory: &DynMapperFactory<K, V>,
    combiner: Option<&dyn Combiner<K, V>>,
    partitioner: &dyn Partitioner<K>,
    n_reducers: usize,
    job: &Arc<str>,
) -> MapTaskResult<K, V>
where
    K: Send + Clone + Ord + Hash,
    V: Send + Clone,
{
    // lint:allow(wall-clock-in-sim): per-task meter feeding
    // TaskMeter::wall_secs, not simulated time (DESIGN.md §2).
    let start = Instant::now();
    let mut mapper = factory(task_id);
    let mut ctx: Context<K, V> = Context::new();
    ctx.counters.add(keys::MAP_INPUT_RECORDS, split.len() as u64);
    // RecordReader loop: the split streams records from its backing
    // RecordSource (zero-copy for in-memory files; one decoded block at a
    // time for segment stores, so task memory is bounded by the HDFS block
    // size rather than the dataset size).
    split.for_each_record(|offset, record| mapper.map(offset, record, &mut ctx));
    mapper.cleanup(&mut ctx);
    // Map-side partitioned spill: route every pair to its reducer's bucket
    // HERE, on the task's own thread, then combine each bucket locally.
    // The driver never re-partitions a flat pair stream — it only
    // concatenates per-reducer buckets, like a real shuffle fetching
    // per-partition spill files. (A key always lands in one partition, so
    // partition-then-combine aggregates exactly like combine-then-partition
    // would.)
    let mut buckets: Vec<Vec<(K, V)>> = (0..n_reducers).map(|_| Vec::new()).collect();
    for (k, v) in ctx.take_output() {
        let p = partitioner.partition(&k, n_reducers);
        buckets[p].push((k, v));
    }
    let mut spilled = 0u64;
    for bucket in &mut buckets {
        if let Some(c) = combiner {
            // Combine stage (map-side): fold values per key locally. Sorts
            // the bucket as a side effect (deterministic spills).
            *bucket = combine_pairs(c, std::mem::take(bucket));
        }
        // Without a combiner the raw emission order is kept — generic
        // reducers may be order-sensitive.
        spilled += bucket.len() as u64;
    }
    ctx.counters.add(keys::COMBINE_OUTPUT_TUPLES, spilled);
    ctx.counters.add(
        keys::SHUFFLE_SPILL_PARTITIONS,
        buckets.iter().filter(|b| !b.is_empty()).count() as u64,
    );
    MapTaskResult {
        meter: TaskMeter {
            task_id,
            job: Arc::clone(job),
            counters: ctx.counters,
            preferred_nodes: split.preferred_nodes.clone(),
            wall_secs: start.elapsed().as_secs_f64(),
        },
        buckets,
        aux: ctx.aux,
    }
}

fn run_reduce_task<K, V, O>(
    task_id: usize,
    column: Vec<Vec<(K, V)>>,
    reducer: &dyn Reducer<K, V, Out = O>,
    job: Arc<str>,
) -> (Vec<O>, TaskMeter)
where
    K: Ord,
{
    // lint:allow(wall-clock-in-sim): per-task meter feeding
    // TaskMeter::wall_secs, not simulated time (DESIGN.md §2).
    let start = Instant::now();
    // Hash-grouped merge, in map-task order so per-key value order is
    // deterministic. (A Hadoop-style sort-merge variant was tried and
    // reverted: sorting flat pair vectors measured ~25% slower end-to-end
    // than BTreeMap insertion here — §Perf log.)
    let mut group: BTreeMap<K, Vec<V>> = BTreeMap::new();
    let mut in_tuples = 0u64;
    for bucket in column {
        in_tuples += bucket.len() as u64;
        for (k, v) in bucket {
            group.entry(k).or_default().push(v);
        }
    }
    let mut counters = Counters::new();
    counters.add(keys::REDUCE_INPUT_TUPLES, in_tuples);
    let mut outputs = Vec::new();
    for (k, vs) in &group {
        if let Some(o) = reducer.reduce(k, vs) {
            outputs.push(o);
        }
    }
    counters.add(keys::REDUCE_OUTPUT_RECORDS, outputs.len() as u64);
    let meter = TaskMeter {
        task_id,
        job,
        counters,
        preferred_nodes: Vec::new(),
        wall_secs: start.elapsed().as_secs_f64(),
    };
    (outputs, meter)
}

/// Group `pairs` by key and fold each group through the combiner,
/// returning the bucket sorted by key (deterministic spills).
fn combine_pairs<K: Ord + Clone + Hash, V, C: Combiner<K, V> + ?Sized>(
    combiner: &C,
    pairs: Vec<(K, V)>,
) -> Vec<(K, V)> {
    let mut grouped: HashMap<K, Vec<V>> = HashMap::with_capacity(pairs.len() / 2 + 1);
    for (k, v) in pairs {
        grouped.entry(k).or_default().push(v);
    }
    let mut out: Vec<(K, V)> = grouped
        .into_iter()
        .map(|(k, mut vs)| {
            let v = combiner.combine(&k, &mut vs);
            (k, v)
        })
        .collect();
    // Deterministic downstream order regardless of hash iteration.
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::TransactionDb;
    use crate::hdfs;
    use crate::itemset::Itemset;
    use crate::mapreduce::api::{MinSupportReducer, SumCombiner};

    /// Word-count analog: emit (item, 1) per item — the paper's Job1 mapper.
    struct ItemMapper;
    impl Mapper for ItemMapper {
        type K = u32;
        type V = u64;
        fn map(&mut self, _off: usize, record: &Itemset, ctx: &mut Context<u32, u64>) {
            for &i in record {
                ctx.write(i, 1);
            }
        }
    }

    fn splits_for(db: &TransactionDb, per_split: usize) -> Vec<InputSplit> {
        let f = hdfs::put(db, per_split, 4, 3, 1);
        hdfs::nline_splits(&f, per_split)
    }

    fn demo_db() -> TransactionDb {
        TransactionDb::new(
            "d",
            4,
            vec![vec![0, 1], vec![0, 2], vec![0, 1, 3], vec![1], vec![0]],
        )
    }

    fn wordcount_job(
        db: &TransactionDb,
        n_reducers: usize,
        min_count: u64,
    ) -> JobBuilder<u32, u64, (u32, u64)> {
        JobBuilder::new("wc")
            .splits(splits_for(db, 2))
            .mapper(|_| ItemMapper)
            .combiner(SumCombiner)
            .reducer(MinSupportReducer { min_count })
            .reducers(n_reducers)
    }

    fn run_wordcount(workers: usize, n_reducers: usize, min_count: u64) -> JobOutput<(u32, u64)> {
        let db = demo_db();
        Executor::new(workers)
            .submit(wordcount_job(&db, n_reducers, min_count))
            .wait()
            .expect("no cancel token attached")
    }

    fn sorted(mut v: Vec<(u32, u64)>) -> Vec<(u32, u64)> {
        v.sort();
        v
    }

    #[test]
    fn wordcount_correct() {
        let out = run_wordcount(1, 2, 1);
        assert_eq!(out.name, "wc");
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3), (2, 1), (3, 1)]);
    }

    #[test]
    fn min_support_filter_applies() {
        let out = run_wordcount(1, 2, 3);
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3)]);
    }

    #[test]
    fn parallel_equals_sequential() {
        // Pooled mappers AND pooled reducers must be invisible in the
        // output, across the workers × n_reducers grid.
        let baseline = sorted(run_wordcount(1, 1, 1).outputs);
        for workers in [1, 4] {
            for n_reducers in [1, 3] {
                let out = run_wordcount(workers, n_reducers, 1);
                assert_eq!(out.reduce_meters.len(), n_reducers);
                assert_eq!(
                    sorted(out.outputs),
                    baseline,
                    "workers={workers} n_reducers={n_reducers}"
                );
            }
        }
    }

    #[test]
    fn pooled_execution_is_deterministic() {
        // Not just the same multiset: byte-identical output ORDER, because
        // spills are pre-sorted and reduce outputs concatenate in task
        // order regardless of which pool thread ran them.
        let seq = run_wordcount(1, 3, 1).outputs;
        for _ in 0..5 {
            assert_eq!(run_wordcount(4, 3, 1).outputs, seq);
        }
    }

    #[test]
    fn counters_account_for_combine() {
        let out = run_wordcount(1, 1, 1);
        assert_eq!(out.counters.get(keys::MAP_INPUT_RECORDS), 5);
        assert_eq!(out.counters.get(keys::MAP_OUTPUT_TUPLES), 9); // raw item writes
        // 3 splits: {01,02}->(0:2,1:1,2:1)=3, {013,1}->(0:1,1:2,3:1)=3, {0}->1
        assert_eq!(out.counters.get(keys::COMBINE_OUTPUT_TUPLES), 7);
        assert_eq!(out.counters.get(keys::REDUCE_INPUT_TUPLES), 7);
        assert_eq!(out.counters.get(keys::REDUCE_OUTPUT_RECORDS), 4);
    }

    #[test]
    fn spill_partitions_metered() {
        // 3 map tasks spilling into 2 partitions each: at most 6 non-empty
        // buckets, at least one per non-empty task.
        let out = run_wordcount(1, 2, 1);
        let spills = out.counters.get(keys::SHUFFLE_SPILL_PARTITIONS);
        assert!((3..=6).contains(&spills), "spills {spills}");
        // Single reducer: exactly one bucket per task.
        let out = run_wordcount(1, 1, 1);
        assert_eq!(out.counters.get(keys::SHUFFLE_SPILL_PARTITIONS), 3);
    }

    #[test]
    fn task_meters_present() {
        let out = run_wordcount(1, 2, 1);
        assert_eq!(out.map_meters.len(), 3);
        assert_eq!(out.reduce_meters.len(), 2);
        assert!(out.map_meters.iter().all(|m| m.wall_secs >= 0.0));
        assert!(!out.map_meters[0].preferred_nodes.is_empty());
    }

    #[test]
    fn job_name_reaches_meters() {
        let out = run_wordcount(1, 2, 1);
        assert_eq!(out.name, "wc");
        assert!(out.map_meters.iter().all(|m| &*m.job == "wc"));
        assert!(out.reduce_meters.iter().all(|m| &*m.job == "wc"));
    }

    #[test]
    fn reducer_count_respected() {
        let out = run_wordcount(1, 4, 1);
        assert_eq!(out.reduce_meters.len(), 4);
        let total: u64 =
            out.reduce_meters.iter().map(|m| m.counters.get(keys::REDUCE_INPUT_TUPLES)).sum();
        assert_eq!(total, 7);
    }

    /// Mapper that reports through the aux side-channel.
    struct AuxMapper(u64);
    impl Mapper for AuxMapper {
        type K = u32;
        type V = u64;
        fn map(&mut self, _o: usize, _r: &Itemset, _c: &mut Context<u32, u64>) {}
        fn cleanup(&mut self, ctx: &mut Context<u32, u64>) {
            ctx.set_aux(keys::CANDIDATES, self.0);
        }
    }

    fn run_aux_job(
        factory: impl Fn(usize) -> AuxMapper + Send + Sync + 'static,
    ) -> JobOutput<(u32, u64)> {
        let db = demo_db();
        Executor::new(1)
            .submit(
                JobBuilder::new("aux")
                    .splits(splits_for(&db, 2))
                    .mapper(factory)
                    .reducer(MinSupportReducer { min_count: 1 }),
            )
            .wait()
            .expect("no cancel token attached")
    }

    #[test]
    fn aux_takes_max_across_tasks() {
        let out = run_aux_job(|task| AuxMapper(10 + task as u64));
        assert_eq!(out.aux.get(keys::CANDIDATES), Some(&12)); // 3 tasks: 10,11,12
    }

    #[test]
    fn divergent_aux_values_are_detected() {
        // Per-task values 10,11,12: legal for a generic job, but flagged so
        // an Apriori driver (where all tasks must agree) can assert.
        let out = run_aux_job(|task| AuxMapper(10 + task as u64));
        assert_eq!(out.aux_divergence, vec![keys::CANDIDATES]);
    }

    #[test]
    fn agreeing_aux_values_are_not_flagged() {
        let out = run_aux_job(|_| AuxMapper(7));
        assert_eq!(out.aux.get(keys::CANDIDATES), Some(&7));
        assert!(out.aux_divergence.is_empty());
    }

    #[test]
    fn no_combiner_shuffles_raw_tuples() {
        let db = demo_db();
        let out = Executor::new(1)
            .submit(
                JobBuilder::new("raw")
                    .splits(splits_for(&db, 2))
                    .mapper(|_| ItemMapper)
                    .reducer(MinSupportReducer { min_count: 1 })
                    .reducers(2),
            )
            .wait()
            .expect("no cancel token attached");
        assert_eq!(out.counters.get(keys::COMBINE_OUTPUT_TUPLES), 9); // = raw
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3), (2, 1), (3, 1)]);
    }

    // ---- executor-specific behavior ------------------------------------

    #[test]
    fn task_events_stream_in_execution_order() {
        let db = demo_db();
        let mut events: Vec<(TaskKind, usize, bool, usize)> = Vec::new();
        let out = Executor::new(2)
            .submit(wordcount_job(&db, 2, 1))
            .wait_with(|ev| match ev {
                TaskEvent::Started { job, kind, task, of } => {
                    assert_eq!(&*job, "wc");
                    events.push((kind, task, false, of));
                }
                TaskEvent::Finished { kind, task, of, .. } => events.push((kind, task, true, of)),
            })
            .expect("no cancel token attached");
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3), (2, 1), (3, 1)]);
        // 3 map tasks and 2 reduce tasks, each started once and finished
        // once, with correct phase totals.
        for kind in [TaskKind::Map, TaskKind::Reduce] {
            let (n, of) = match kind {
                TaskKind::Map => (3, 3),
                TaskKind::Reduce => (2, 2),
            };
            for task in 0..n {
                let started = events.iter().position(|e| *e == (kind, task, false, of));
                let finished = events.iter().position(|e| *e == (kind, task, true, of));
                let (s, f) = (started.expect("started event"), finished.expect("finished event"));
                assert!(s < f, "{kind} task {task}: finish before start");
            }
        }
        assert_eq!(events.len(), 2 * (3 + 2));
        // Phases do not interleave: every map event precedes every reduce
        // event (the reduce phase is enqueued only after the map barrier).
        let first_reduce = events.iter().position(|e| e.0 == TaskKind::Reduce).unwrap();
        assert!(events[..first_reduce].iter().all(|e| e.0 == TaskKind::Map));
    }

    #[test]
    fn pre_cancelled_token_skips_every_task() {
        let db = demo_db();
        let token = CancelToken::new();
        token.cancel();
        let mut saw_event = false;
        let err = Executor::new(2)
            .submit(wordcount_job(&db, 2, 1).cancel_token(token))
            .wait_with(|_| saw_event = true)
            .expect_err("a pre-cancelled job must not produce output");
        assert_eq!(err, JobError::Cancelled);
        assert!(!saw_event, "skipped tasks must not emit events");
    }

    #[test]
    fn cancel_during_map_phase_stops_before_reduce() {
        let db = demo_db();
        let token = CancelToken::new();
        let handle = Executor::new(1).submit(wordcount_job(&db, 2, 1).cancel_token(token.clone()));
        // Cancel from the event stream: by the time the map phase drains,
        // the token is set, so the reduce tasks (at minimum) are skipped.
        let err = handle
            .wait_with(|ev| {
                if matches!(ev, TaskEvent::Finished { kind: TaskKind::Map, .. }) {
                    token.cancel();
                }
            })
            .expect_err("cancelled mid-job");
        assert_eq!(err, JobError::Cancelled);
    }

    #[test]
    fn handle_cancel_uses_the_job_token() {
        let db = demo_db();
        let handle = Executor::new(1).submit(wordcount_job(&db, 2, 1));
        handle.cancel();
        assert!(handle.cancel_token().is_cancelled());
        // The job may have raced to completion before the cancel landed;
        // both outcomes are legal, but nothing else.
        match handle.wait() {
            Err(JobError::Cancelled) => {}
            Ok(out) => assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3), (2, 1), (3, 1)]),
        }
    }

    #[test]
    fn dropping_a_handle_aborts_without_wedging_the_pool() {
        let db = demo_db();
        let executor = Executor::new(1);
        drop(executor.submit(wordcount_job(&db, 2, 1)));
        // The shared pool keeps serving jobs afterwards.
        let out = executor.submit(wordcount_job(&db, 2, 1)).wait().expect("second job");
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3), (2, 1), (3, 1)]);
    }

    /// Mapper whose map() panics — the driver must see the panic.
    struct PanicMapper;
    impl Mapper for PanicMapper {
        type K = u32;
        type V = u64;
        fn map(&mut self, _o: usize, _r: &Itemset, _c: &mut Context<u32, u64>) {
            panic!("mapper boom");
        }
    }

    #[test]
    fn task_panics_propagate_to_wait_and_spare_the_pool() {
        let db = demo_db();
        let executor = Executor::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            executor
                .submit(
                    JobBuilder::new("boom")
                        .splits(splits_for(&db, 2))
                        .mapper(|_| PanicMapper)
                        .reducer(MinSupportReducer { min_count: 1 }),
                )
                .wait()
        }));
        let payload = result.expect_err("the mapper panic must reach the driver");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "mapper boom");
        // The worker threads caught the panic: the pool still works.
        let out = executor.submit(wordcount_job(&db, 2, 1)).wait().expect("pool survives");
        assert_eq!(sorted(out.outputs), vec![(0, 4), (1, 3), (2, 1), (3, 1)]);
    }

    #[test]
    fn one_executor_serves_concurrent_jobs_within_budget() {
        let db = demo_db();
        let executor = Executor::new(2);
        let baseline = sorted(run_wordcount(1, 3, 1).outputs);
        std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for _ in 0..6 {
                let executor = &executor;
                let db = &db;
                let baseline = &baseline;
                joins.push(scope.spawn(move || {
                    let out = executor.submit(wordcount_job(db, 3, 1)).wait().expect("job");
                    assert_eq!(&sorted(out.outputs), baseline);
                }));
            }
            for join in joins {
                join.join().expect("concurrent submitter panicked");
            }
        });
        // Six concurrent jobs, ONE bounded pool: never more than 2 tasks
        // in flight — the old per-job scoped batches would have peaked at
        // 6 × min(workers, tasks) threads.
        let hwm = executor.high_water_mark();
        assert!((1..=2).contains(&hwm), "high water {hwm}");
    }

    #[test]
    #[should_panic(expected = "submitted without a mapper")]
    fn submitting_without_a_mapper_is_a_driver_bug() {
        let db = demo_db();
        let job: JobBuilder<u32, u64, (u32, u64)> = JobBuilder::new("half-built")
            .splits(splits_for(&db, 2))
            .reducer(MinSupportReducer { min_count: 1 });
        let _ = Executor::new(1).submit(job);
    }
}
