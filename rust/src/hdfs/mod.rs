//! HDFS-like storage substrate: files are sequences of transaction records,
//! broken into fixed-size line blocks, replicated across DataNodes, and cut
//! into NLineInputFormat-style input splits for the MapReduce engine.
//!
//! The paper configures `setNumLinesPerSplit` per dataset (§5.2: 1K lines
//! for c20d10k/mushroom -> 10/9 mappers, 400 for chess -> 8 mappers); split
//! construction here mirrors that. Replica placement feeds the scheduler's
//! data-locality preference.
//!
//! Storage is pluggable behind [`RecordSource`] (DESIGN.md §7): the
//! in-memory backend keeps the whole record vector resident (fast path for
//! the paper-sized datasets), while [`segment::SegmentSource`] backs blocks
//! with on-disk segment files decoded lazily one block at a time, so map
//! tasks over a T10I4D100K-class file never hold more than one block of
//! records in memory.

pub mod segment;

use crate::dataset::TransactionDb;
use crate::itemset::Itemset;
use crate::util::rng::Rng;
use std::ops::Range;
use std::sync::Arc;

/// Index of a simulated DataNode.
pub type NodeId = usize;

/// Abstract record storage: a fixed-length sequence of transactions that
/// can be visited in order over any sub-range.
///
/// `for_each` is an internal iterator so backends control buffering: the
/// in-memory source hands out borrowed slices with zero copies, while the
/// segment source decodes one on-disk block at a time into a reusable
/// buffer bounded by `block_lines` records.
pub trait RecordSource: Send + Sync + std::fmt::Debug {
    /// Total number of records in the file.
    fn len(&self) -> usize;

    /// Whether the file holds no records.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit records `range` in order as `(offset, record)` pairs.
    fn for_each(&self, range: Range<usize>, f: &mut dyn FnMut(usize, &Itemset));
}

/// The fully-resident backend: an `Arc`-shared record vector (the original
/// representation, kept as the fast path for small datasets).
#[derive(Debug, Clone)]
pub struct InMemorySource {
    records: Arc<Vec<Itemset>>,
}

impl InMemorySource {
    /// Wrap an owned record vector.
    pub fn new(records: Vec<Itemset>) -> Self {
        Self { records: Arc::new(records) }
    }
}

impl RecordSource for InMemorySource {
    fn len(&self) -> usize {
        self.records.len()
    }

    fn for_each(&self, range: Range<usize>, f: &mut dyn FnMut(usize, &Itemset)) {
        for (i, r) in self.records[range.clone()].iter().enumerate() {
            f(range.start + i, r);
        }
    }
}

/// One HDFS block: a line range plus the nodes holding replicas.
#[derive(Debug, Clone)]
pub struct Block {
    /// The records this block covers (line numbers in the file).
    pub range: Range<usize>,
    /// DataNodes holding a replica of this block.
    pub replicas: Vec<NodeId>,
}

/// A stored file: a record source plus its block map.
#[derive(Debug, Clone)]
pub struct HdfsFile {
    /// Dataset name (drives per-dataset defaults in the registry).
    pub name: String,
    /// Backing storage (in-memory or on-disk segments).
    pub source: Arc<dyn RecordSource>,
    /// Size of the dense item universe `0..n_items`.
    pub n_items: usize,
    /// Records per block (the HDFS block size, in lines).
    pub block_lines: usize,
    /// Block map with replica placement.
    pub blocks: Vec<Block>,
}

impl HdfsFile {
    /// Total number of records in the file.
    pub fn len(&self) -> usize {
        self.source.len()
    }

    /// Whether the file holds no records.
    pub fn is_empty(&self) -> bool {
        self.source.is_empty()
    }

    /// Minimum support count for a fractional threshold (ceil, >= 1) —
    /// mirrors [`TransactionDb::min_count`] for files that were never
    /// materialized in memory.
    pub fn min_count(&self, min_sup: f64) -> u64 {
        ((min_sup * self.len() as f64).ceil() as u64).max(1)
    }
}

/// One input split handed to a single map task.
#[derive(Debug, Clone)]
pub struct InputSplit {
    /// Backing storage shared with the owning [`HdfsFile`].
    pub source: Arc<dyn RecordSource>,
    /// The records this split covers.
    pub range: Range<usize>,
    /// Nodes that hold a replica of the split's first block (locality hint).
    pub preferred_nodes: Vec<NodeId>,
}

impl InputSplit {
    /// Number of records in the split.
    pub fn len(&self) -> usize {
        self.range.len()
    }

    /// Whether the split covers no records.
    pub fn is_empty(&self) -> bool {
        self.range.is_empty()
    }

    /// Visit the split's records as a RecordReader would: `(byte-offset-like
    /// key, record)` pairs in line order. Streaming backends decode at most
    /// one block at a time, so a map task's resident buffer is bounded by
    /// the block size, not the dataset size.
    pub fn for_each_record(&self, mut f: impl FnMut(usize, &Itemset)) {
        self.source.for_each(self.range.clone(), &mut f);
    }

    /// Materialize the split's records (tests and small consumers only —
    /// defeats the streaming bound on purpose).
    pub fn collect_records(&self) -> Vec<(usize, Itemset)> {
        let mut out = Vec::with_capacity(self.len());
        self.for_each_record(|off, r| out.push((off, r.clone())));
        out
    }
}

/// Default HDFS replication factor.
pub const DEFAULT_REPLICATION: usize = 3;

/// Build a block map with pipeline replica placement over `n_records`
/// records: first replica on a random node, the rest on successive distinct
/// nodes (rack-unaware variant of the HDFS default).
fn place_blocks(
    n_records: usize,
    block_lines: usize,
    n_nodes: usize,
    replication: usize,
    seed: u64,
) -> Vec<Block> {
    assert!(block_lines > 0 && n_nodes > 0);
    let replication = replication.min(n_nodes).max(1);
    let mut rng = Rng::new(seed ^ 0x4DF5);
    let mut blocks = Vec::new();
    let mut start = 0;
    while start < n_records {
        let end = (start + block_lines).min(n_records);
        let first = rng.below(n_nodes as u64) as usize;
        let replicas: Vec<NodeId> = (0..replication).map(|r| (first + r) % n_nodes).collect();
        blocks.push(Block { range: start..end, replicas });
        start = end;
    }
    blocks
}

/// Store an in-memory database as an HDFS file across `n_nodes` DataNodes.
pub fn put(
    db: &TransactionDb,
    block_lines: usize,
    n_nodes: usize,
    replication: usize,
    seed: u64,
) -> HdfsFile {
    let blocks = place_blocks(db.txns.len(), block_lines, n_nodes, replication, seed);
    HdfsFile {
        name: db.name.clone(),
        source: Arc::new(InMemorySource::new(db.txns.clone())),
        n_items: db.n_items,
        block_lines,
        blocks,
    }
}

/// Store an on-disk segment store as an HDFS file across `n_nodes`
/// DataNodes. Blocks follow the store's own segment granularity
/// (`SegmentSource::block_lines`), so each simulated HDFS block is exactly
/// one lazily-decoded segment file. Takes an `Arc` so the caller can keep
/// a handle for observability (e.g.
/// [`segment::SegmentSource::peak_resident_records`]).
pub fn put_segmented(
    src: Arc<segment::SegmentSource>,
    n_nodes: usize,
    replication: usize,
    seed: u64,
) -> HdfsFile {
    let blocks = place_blocks(src.len(), src.block_lines(), n_nodes, replication, seed);
    HdfsFile {
        name: src.name().to_string(),
        n_items: src.n_items(),
        block_lines: src.block_lines(),
        source: src,
        blocks,
    }
}

/// Cut a file into NLine splits of `lines_per_split` records each.
pub fn nline_splits(file: &HdfsFile, lines_per_split: usize) -> Vec<InputSplit> {
    assert!(lines_per_split > 0);
    let n = file.len();
    let mut out = Vec::with_capacity(n.div_ceil(lines_per_split));
    let mut start = 0;
    while start < n {
        let end = (start + lines_per_split).min(n);
        let preferred = file
            .blocks
            .iter()
            .find(|b| b.range.contains(&start))
            .map(|b| b.replicas.clone())
            .unwrap_or_default();
        out.push(InputSplit {
            source: Arc::clone(&file.source),
            range: start..end,
            preferred_nodes: preferred,
        });
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::TransactionDb;

    fn db(n: usize) -> TransactionDb {
        TransactionDb::new("d", 10, (0..n).map(|i| vec![(i % 10) as u32]).collect())
    }

    #[test]
    fn blocks_cover_file_without_overlap() {
        let f = put(&db(2500), 1000, 4, 3, 1);
        assert_eq!(f.blocks.len(), 3);
        assert_eq!(f.blocks[0].range, 0..1000);
        assert_eq!(f.blocks[2].range, 2000..2500);
        for b in &f.blocks {
            assert_eq!(b.replicas.len(), 3);
            let set: std::collections::HashSet<_> = b.replicas.iter().collect();
            assert_eq!(set.len(), 3, "replicas must be distinct nodes");
        }
    }

    #[test]
    fn replication_capped_by_nodes() {
        let f = put(&db(10), 5, 2, 3, 1);
        assert!(f.blocks.iter().all(|b| b.replicas.len() == 2));
    }

    #[test]
    fn splits_cover_all_records_once() {
        let f = put(&db(2500), 1000, 4, 3, 1);
        let splits = nline_splits(&f, 400);
        assert_eq!(splits.len(), 7); // ceil(2500/400)
        let mut seen = vec![false; 2500];
        for s in &splits {
            s.for_each_record(|off, _| {
                assert!(!seen[off], "record {off} in two splits");
                seen[off] = true;
            });
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn paper_mapper_counts() {
        // §5.2: 10 map tasks for c20d10k (10k lines / 1k), 8 for chess
        // (3196 / 400), 9 for mushroom (8124 / 1k).
        let f = put(&db(10_000), 1000, 4, 3, 1);
        assert_eq!(nline_splits(&f, 1000).len(), 10);
        let f = put(&db(3196), 1000, 4, 3, 1);
        assert_eq!(nline_splits(&f, 400).len(), 8);
        let f = put(&db(8124), 1000, 4, 3, 1);
        assert_eq!(nline_splits(&f, 1000).len(), 9);
    }

    #[test]
    fn preferred_nodes_come_from_block_map() {
        let f = put(&db(100), 10, 5, 2, 7);
        let splits = nline_splits(&f, 10);
        for (s, b) in splits.iter().zip(&f.blocks) {
            assert_eq!(s.preferred_nodes, b.replicas);
        }
    }

    #[test]
    fn split_iteration_yields_offsets() {
        let f = put(&db(30), 10, 2, 1, 3);
        let splits = nline_splits(&f, 25);
        let (offs, _): (Vec<usize>, Vec<_>) = splits[1].collect_records().into_iter().unzip();
        assert_eq!(offs, (25..30).collect::<Vec<_>>());
    }

    #[test]
    fn file_min_count_matches_db() {
        let d = db(100);
        let f = put(&d, 10, 2, 1, 3);
        for ms in [0.0, 0.013, 0.5, 1.0] {
            assert_eq!(f.min_count(ms), d.min_count(ms));
        }
    }
}
