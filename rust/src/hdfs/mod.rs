//! HDFS-like storage substrate: files are sequences of transaction records,
//! broken into fixed-size line blocks, replicated across DataNodes, and cut
//! into NLineInputFormat-style input splits for the MapReduce engine.
//!
//! The paper configures `setNumLinesPerSplit` per dataset (§5.2: 1K lines
//! for c20d10k/mushroom -> 10/9 mappers, 400 for chess -> 8 mappers); split
//! construction here mirrors that. Replica placement feeds the scheduler's
//! data-locality preference.

use crate::dataset::TransactionDb;
use crate::itemset::Itemset;
use crate::util::rng::Rng;
use std::ops::Range;
use std::sync::Arc;

pub type NodeId = usize;

/// One HDFS block: a line range plus the nodes holding replicas.
#[derive(Debug, Clone)]
pub struct Block {
    pub range: Range<usize>,
    pub replicas: Vec<NodeId>,
}

/// A stored file: immutable records plus its block map.
#[derive(Debug, Clone)]
pub struct HdfsFile {
    pub name: String,
    pub records: Arc<Vec<Itemset>>,
    pub n_items: usize,
    pub block_lines: usize,
    pub blocks: Vec<Block>,
}

/// One input split handed to a single map task.
#[derive(Debug, Clone)]
pub struct InputSplit {
    pub records: Arc<Vec<Itemset>>,
    pub range: Range<usize>,
    /// Nodes that hold a replica of the split's first block (locality hint).
    pub preferred_nodes: Vec<NodeId>,
}

impl InputSplit {
    pub fn len(&self) -> usize {
        self.range.len()
    }
    pub fn is_empty(&self) -> bool {
        self.range.is_empty()
    }
    /// Iterate `(byte-offset-like key, record)` pairs, as a RecordReader.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &Itemset)> {
        self.records[self.range.clone()].iter().enumerate().map(move |(i, r)| (self.range.start + i, r))
    }
}

/// Default HDFS replication factor.
pub const DEFAULT_REPLICATION: usize = 3;

/// Store a database as an HDFS file across `n_nodes` DataNodes.
pub fn put(
    db: &TransactionDb,
    block_lines: usize,
    n_nodes: usize,
    replication: usize,
    seed: u64,
) -> HdfsFile {
    assert!(block_lines > 0 && n_nodes > 0);
    let replication = replication.min(n_nodes).max(1);
    let mut rng = Rng::new(seed ^ 0x4DF5);
    let records = Arc::new(db.txns.clone());
    let mut blocks = Vec::new();
    let mut start = 0;
    while start < records.len() {
        let end = (start + block_lines).min(records.len());
        // Pipeline placement: first replica on a random node, the rest on
        // successive distinct nodes (rack-unaware variant of HDFS default).
        let first = rng.below(n_nodes as u64) as usize;
        let replicas: Vec<NodeId> = (0..replication).map(|r| (first + r) % n_nodes).collect();
        blocks.push(Block { range: start..end, replicas });
        start = end;
    }
    HdfsFile { name: db.name.clone(), records, n_items: db.n_items, block_lines, blocks }
}

/// Cut a file into NLine splits of `lines_per_split` records each.
pub fn nline_splits(file: &HdfsFile, lines_per_split: usize) -> Vec<InputSplit> {
    assert!(lines_per_split > 0);
    let n = file.records.len();
    let mut out = Vec::with_capacity(n.div_ceil(lines_per_split));
    let mut start = 0;
    while start < n {
        let end = (start + lines_per_split).min(n);
        let preferred = file
            .blocks
            .iter()
            .find(|b| b.range.contains(&start))
            .map(|b| b.replicas.clone())
            .unwrap_or_default();
        out.push(InputSplit {
            records: Arc::clone(&file.records),
            range: start..end,
            preferred_nodes: preferred,
        });
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::TransactionDb;

    fn db(n: usize) -> TransactionDb {
        TransactionDb::new("d", 10, (0..n).map(|i| vec![(i % 10) as u32]).collect())
    }

    #[test]
    fn blocks_cover_file_without_overlap() {
        let f = put(&db(2500), 1000, 4, 3, 1);
        assert_eq!(f.blocks.len(), 3);
        assert_eq!(f.blocks[0].range, 0..1000);
        assert_eq!(f.blocks[2].range, 2000..2500);
        for b in &f.blocks {
            assert_eq!(b.replicas.len(), 3);
            let set: std::collections::HashSet<_> = b.replicas.iter().collect();
            assert_eq!(set.len(), 3, "replicas must be distinct nodes");
        }
    }

    #[test]
    fn replication_capped_by_nodes() {
        let f = put(&db(10), 5, 2, 3, 1);
        assert!(f.blocks.iter().all(|b| b.replicas.len() == 2));
    }

    #[test]
    fn splits_cover_all_records_once() {
        let f = put(&db(2500), 1000, 4, 3, 1);
        let splits = nline_splits(&f, 400);
        assert_eq!(splits.len(), 7); // ceil(2500/400)
        let mut seen = vec![false; 2500];
        for s in &splits {
            for (off, _) in s.iter() {
                assert!(!seen[off], "record {off} in two splits");
                seen[off] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn paper_mapper_counts() {
        // §5.2: 10 map tasks for c20d10k (10k lines / 1k), 8 for chess
        // (3196 / 400), 9 for mushroom (8124 / 1k).
        let f = put(&db(10_000), 1000, 4, 3, 1);
        assert_eq!(nline_splits(&f, 1000).len(), 10);
        let f = put(&db(3196), 1000, 4, 3, 1);
        assert_eq!(nline_splits(&f, 400).len(), 8);
        let f = put(&db(8124), 1000, 4, 3, 1);
        assert_eq!(nline_splits(&f, 1000).len(), 9);
    }

    #[test]
    fn preferred_nodes_come_from_block_map() {
        let f = put(&db(100), 10, 5, 2, 7);
        let splits = nline_splits(&f, 10);
        for (s, b) in splits.iter().zip(&f.blocks) {
            assert_eq!(s.preferred_nodes, b.replicas);
        }
    }

    #[test]
    fn split_iter_yields_offsets() {
        let f = put(&db(30), 10, 2, 1, 3);
        let splits = nline_splits(&f, 25);
        let (offs, _): (Vec<usize>, Vec<_>) = splits[1].iter().unzip();
        assert_eq!(offs, (25..30).collect::<Vec<_>>());
    }
}
