//! On-disk segment store: the streaming [`RecordSource`] backend.
//!
//! A segment store is a directory holding one FIMI text file per HDFS
//! block (`block-00000.txt`, `block-00001.txt`, ...) plus a small
//! `manifest` describing the file. [`SegmentWriter`] streams records into
//! the store block by block (a generator never materializes the dataset);
//! [`SegmentSource`] decodes blocks lazily during
//! [`RecordSource::for_each`], holding at most one block of records
//! resident at a time. See DESIGN.md §7.

use super::RecordSource;
use crate::itemset::Itemset;
use std::io::{BufWriter, Write as _};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Manifest file name inside a segment store directory.
pub const MANIFEST: &str = "manifest";

/// Errors opening or writing a segment store.
#[derive(Debug)]
pub enum SegmentError {
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// The manifest is missing a key or holds an unparsable value.
    BadManifest(String),
    /// An empty transaction was pushed (record `index`, 0-based). Empty
    /// lines are skipped on decode, so storing one would desynchronize
    /// record offsets.
    EmptyTransaction(usize),
    /// A dataset name that cannot name a store (no disk state involved —
    /// used by name-keyed store builders like the registry's quest cache).
    InvalidName(String),
    /// An append-reopen asked for a store shape that contradicts the
    /// published manifest. Appends must keep `block_lines` (record offsets
    /// are block-aligned everywhere) and the declared item universe.
    AppendMismatch {
        /// Manifest field that disagreed (`"block_lines"` or `"n_items"`).
        field: &'static str,
        /// Value recorded in the published manifest.
        existing: usize,
        /// Value the append caller asked for.
        requested: usize,
    },
}

impl std::fmt::Display for SegmentError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SegmentError::Io(e) => write!(f, "segment store io error: {e}"),
            SegmentError::BadManifest(msg) => write!(f, "bad segment manifest: {msg}"),
            SegmentError::EmptyTransaction(i) => {
                write!(f, "transaction {i} is empty; segment stores cannot hold empty records")
            }
            SegmentError::InvalidName(msg) => write!(f, "invalid dataset name: {msg}"),
            SegmentError::AppendMismatch { field, existing, requested } => write!(
                f,
                "cannot append to segment store: {field} is {existing} in the manifest \
                 but {requested} was requested"
            ),
        }
    }
}

impl std::error::Error for SegmentError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SegmentError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SegmentError {
    fn from(e: std::io::Error) -> Self {
        SegmentError::Io(e)
    }
}

fn block_path(dir: &Path, index: usize) -> PathBuf {
    dir.join(format!("block-{index:05}.txt"))
}

/// Streams records into a new segment store, rolling over to a fresh block
/// file every `block_lines` records. Memory use is one `BufWriter`, never
/// the dataset.
///
/// Writes land in a `<dir>.partial-<pid>-<seq>` staging directory (unique
/// per writer, even across threads of one process) and move into place
/// with a `rename` when [`SegmentWriter::finish`] has written the manifest
/// — so a reader can never observe a store whose manifest exists but whose
/// blocks are still being (re)written. A writer dropped before `finish`
/// removes its staging directory.
pub struct SegmentWriter {
    /// Final store location, published on `finish`.
    dest: PathBuf,
    /// Staging directory all writes go to.
    dir: PathBuf,
    name: String,
    block_lines: usize,
    writer: Option<BufWriter<std::fs::File>>,
    in_block: usize,
    n_blocks: usize,
    n_records: usize,
    max_item: u32,
    declared_n_items: Option<usize>,
    /// Set once the staging dir was renamed away (suppresses Drop cleanup).
    published: bool,
}

impl SegmentWriter {
    /// Create a store that will be published at `dir` (an existing store
    /// there is replaced on [`SegmentWriter::finish`]).
    pub fn create(
        dir: impl Into<PathBuf>,
        name: impl Into<String>,
        block_lines: usize,
    ) -> Result<Self, SegmentError> {
        assert!(block_lines > 0);
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let dest = dir.into();
        let mut staging = dest.as_os_str().to_os_string();
        staging.push(format!(
            ".partial-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let dir = PathBuf::from(staging);
        // A crashed run with the same pid+seq would corrupt block
        // numbering — start clean.
        if dir.exists() {
            std::fs::remove_dir_all(&dir)?;
        }
        std::fs::create_dir_all(&dir)?;
        Ok(Self {
            dest,
            dir,
            name: name.into(),
            block_lines,
            writer: None,
            in_block: 0,
            n_blocks: 0,
            n_records: 0,
            max_item: 0,
            declared_n_items: None,
            published: false,
        })
    }

    /// Reopen the published store at `dir` for append: load its manifest,
    /// carry the existing blocks into a fresh staging directory, and
    /// continue pushing records after the last one. The publish path is
    /// the same rename-aside dance as [`SegmentWriter::finish`] — readers
    /// of the old store stay consistent until the grown store replaces it
    /// wholesale, and a writer dropped before `finish` leaves the
    /// published store untouched.
    ///
    /// `block_lines` and `n_items` must match the manifest: block-aligned
    /// record offsets and the dense item universe are part of every
    /// downstream consumer's contract, so a disagreement is a typed
    /// [`SegmentError::AppendMismatch`], never a silent rewrite.
    pub fn append(
        dir: impl Into<PathBuf>,
        n_items: usize,
        block_lines: usize,
    ) -> Result<Self, SegmentError> {
        let dest = dir.into();
        let existing = open(&dest)?;
        if existing.block_lines != block_lines {
            return Err(SegmentError::AppendMismatch {
                field: "block_lines",
                existing: existing.block_lines,
                requested: block_lines,
            });
        }
        if existing.n_items != n_items {
            return Err(SegmentError::AppendMismatch {
                field: "n_items",
                existing: existing.n_items,
                requested: n_items,
            });
        }
        let mut w = Self::create(dest, existing.name.clone(), block_lines)?;
        w.declare_n_items(n_items);
        let full_blocks = existing.n_records / block_lines;
        let partial = existing.n_records % block_lines;
        for b in 0..full_blocks {
            // Full blocks are immutable from here on: hard-link them into
            // staging where the filesystem allows, fall back to a copy.
            let from = block_path(&existing.dir, b);
            let to = block_path(&w.dir, b);
            if std::fs::hard_link(&from, &to).is_err() {
                std::fs::copy(&from, &to)?;
            }
        }
        if partial > 0 {
            // The last block is still growing — copy it (never link:
            // appending through a link would mutate the published store in
            // place) and reopen the copy in append mode.
            let to = block_path(&w.dir, full_blocks);
            std::fs::copy(block_path(&existing.dir, full_blocks), &to)?;
            let f = std::fs::OpenOptions::new().append(true).open(&to)?;
            w.writer = Some(BufWriter::new(f));
            w.in_block = partial;
            w.n_blocks = full_blocks + 1;
        } else {
            w.n_blocks = full_blocks;
        }
        w.n_records = existing.n_records;
        Ok(w)
    }

    /// Declare the item-universe size up front (e.g. a generator's
    /// configured `n_items`). The manifest records
    /// `max(declared, max observed item + 1)`, so a streamed store reports
    /// the same universe as the materialized database would.
    pub fn declare_n_items(&mut self, n_items: usize) {
        self.declared_n_items = Some(n_items);
    }

    /// Append one transaction (canonical item order expected, as produced
    /// by the generators and [`crate::itemset::canonicalize`]). Empty
    /// transactions are rejected — the text format cannot represent them.
    pub fn push(&mut self, txn: &Itemset) -> Result<(), SegmentError> {
        if txn.is_empty() {
            return Err(SegmentError::EmptyTransaction(self.n_records));
        }
        if self.writer.is_none() {
            let f = std::fs::File::create(block_path(&self.dir, self.n_blocks))?;
            self.writer = Some(BufWriter::new(f));
            self.n_blocks += 1;
            self.in_block = 0;
        }
        let w = self.writer.as_mut().expect("writer just ensured");
        crate::dataset::loader::write_txn(w, txn)?;
        if let Some(m) = txn.iter().copied().max() {
            self.max_item = self.max_item.max(m);
        }
        self.in_block += 1;
        self.n_records += 1;
        if self.in_block == self.block_lines {
            self.writer.take().expect("open block").flush()?;
        }
        Ok(())
    }

    /// Flush, write the manifest, publish the staging directory to its
    /// final location via rename (removing any previous store there
    /// first), and reopen the store for reading. If a concurrent writer
    /// publishes the same destination first, its store is used.
    pub fn finish(mut self) -> Result<SegmentSource, SegmentError> {
        if let Some(mut w) = self.writer.take() {
            w.flush()?;
        }
        let observed = if self.n_records == 0 { 0 } else { self.max_item as usize + 1 };
        let n_items = observed.max(self.declared_n_items.unwrap_or(0));
        let manifest = format!(
            "name {}\nn_items {}\nn_records {}\nblock_lines {}\nn_blocks {}\n",
            self.name, n_items, self.n_records, self.block_lines, self.n_blocks,
        );
        std::fs::write(self.dir.join(MANIFEST), manifest)?;
        if self.dest.exists() {
            // Replace an existing store by renaming it aside first, so the
            // not-a-store window at `dest` is two renames, not a recursive
            // delete. (True atomic exchange would need renameat2, which
            // std does not expose; stores are cache artifacts, and a
            // reader racing a replacement regenerates on failure.)
            let mut aside = self.dir.as_os_str().to_os_string();
            aside.push(".old");
            let aside = PathBuf::from(aside);
            std::fs::rename(&self.dest, &aside)?;
            let renamed = std::fs::rename(&self.dir, &self.dest);
            let _ = std::fs::remove_dir_all(&aside);
            match renamed {
                Ok(()) => self.published = true,
                // A concurrent writer slipped its store in between our two
                // renames — same source, so use the winner's.
                Err(_) if self.dest.join(MANIFEST).is_file() => {}
                Err(e) => return Err(e.into()),
            }
        } else {
            match std::fs::rename(&self.dir, &self.dest) {
                Ok(()) => self.published = true,
                // A concurrent writer published the same destination first.
                // Stores for one destination are built from one source, so
                // theirs is as good as ours — drop our staging copy (via
                // Drop) and read the winner.
                Err(_) if self.dest.join(MANIFEST).is_file() => {}
                Err(e) => return Err(e.into()),
            }
        }
        open(&self.dest)
    }
}

impl Drop for SegmentWriter {
    fn drop(&mut self) {
        if !self.published {
            // Close the open block handle before removing the directory
            // (required on platforms that refuse to unlink open files).
            self.writer.take();
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

/// Stream `txns` into a new store published at `dir` — the one-call form
/// of the create / `declare_n_items` / push-loop / `finish` ritual shared
/// by the generators, the registry cache, and the CLI.
pub fn write_store(
    dir: impl Into<PathBuf>,
    name: impl Into<String>,
    block_lines: usize,
    n_items: usize,
    txns: impl IntoIterator<Item = Itemset>,
) -> Result<SegmentSource, SegmentError> {
    let mut w = SegmentWriter::create(dir, name, block_lines)?;
    w.declare_n_items(n_items);
    for t in txns {
        w.push(&t)?;
    }
    w.finish()
}

/// A read-only segment store: block files decoded lazily, one at a time.
pub struct SegmentSource {
    dir: PathBuf,
    name: String,
    n_items: usize,
    n_records: usize,
    block_lines: usize,
    /// High-water mark of records decoded at once (observability for the
    /// streaming-memory bound; see the equivalence tests).
    peak_resident: AtomicUsize,
}

impl std::fmt::Debug for SegmentSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SegmentSource")
            .field("dir", &self.dir)
            .field("name", &self.name)
            .field("n_records", &self.n_records)
            .field("block_lines", &self.block_lines)
            .finish()
    }
}

/// Open an existing segment store directory.
pub fn open(dir: impl Into<PathBuf>) -> Result<SegmentSource, SegmentError> {
    let dir = dir.into();
    let text = std::fs::read_to_string(dir.join(MANIFEST))?;
    let mut name = None;
    let mut fields = std::collections::BTreeMap::new();
    for line in text.lines() {
        let Some((key, value)) = line.split_once(' ') else { continue };
        if key == "name" {
            name = Some(value.to_string());
        } else {
            let v: usize = value.parse().map_err(|_| {
                SegmentError::BadManifest(format!("{key}: cannot parse {value:?}"))
            })?;
            fields.insert(key.to_string(), v);
        }
    }
    let get = |key: &str| {
        fields.get(key).copied().ok_or_else(|| SegmentError::BadManifest(format!("missing {key}")))
    };
    let block_lines = get("block_lines")?;
    if block_lines == 0 {
        return Err(SegmentError::BadManifest("block_lines must be > 0".into()));
    }
    Ok(SegmentSource {
        name: name.ok_or_else(|| SegmentError::BadManifest("missing name".into()))?,
        n_items: get("n_items")?,
        n_records: get("n_records")?,
        block_lines,
        dir,
        peak_resident: AtomicUsize::new(0),
    })
}

/// Whether `dir` already holds a finished segment store.
pub fn exists(dir: &Path) -> bool {
    dir.join(MANIFEST).is_file()
}

impl SegmentSource {
    /// Dataset name recorded in the manifest.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Size of the dense item universe `0..n_items`.
    pub fn n_items(&self) -> usize {
        self.n_items
    }

    /// Records per block file.
    pub fn block_lines(&self) -> usize {
        self.block_lines
    }

    /// Store directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// High-water mark of records resident at once across all `for_each`
    /// calls so far — bounded by [`Self::block_lines`] by construction.
    pub fn peak_resident_records(&self) -> usize {
        self.peak_resident.load(Ordering::Relaxed)
    }

    /// Monotonic store revision: the manifest's record count. Stores are
    /// append-only, so a larger revision at the same path means new
    /// records arrived; [`Self::blocks_since`] enumerates where they live.
    pub fn manifest_rev(&self) -> usize {
        self.n_records
    }

    /// Number of block files in the store (the last one possibly partial).
    pub fn n_blocks(&self) -> usize {
        self.n_records.div_ceil(self.block_lines)
    }

    /// Block indices holding records that did not exist at revision `rev`
    /// (a prior [`Self::manifest_rev`]). A partial block that grew is
    /// included, so its pre-`rev` records re-appear in a whole-block scan
    /// — consumers needing record exactness slice by offset (`rev..len()`)
    /// and use this range only to account rescanned blocks.
    pub fn blocks_since(&self, rev: usize) -> Range<usize> {
        (rev / self.block_lines).min(self.n_blocks())..self.n_blocks()
    }

    /// Decode block `index` into `buf` (clearing it first). Panics with a
    /// readable message on a corrupt store — a segment store is a cache
    /// artifact, so the fix is always "delete the directory and regenerate".
    fn decode_block(&self, index: usize, buf: &mut Vec<Itemset>) {
        buf.clear();
        let path = block_path(&self.dir, index);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("segment store {:?}: cannot read {path:?}: {e}", self.dir));
        for (lno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut t: Itemset = Vec::new();
            for tok in line.split_whitespace() {
                let item: u32 = tok.parse().unwrap_or_else(|_| {
                    panic!("segment store {path:?} line {}: bad item {tok:?}", lno + 1)
                });
                t.push(item);
            }
            crate::itemset::canonicalize(&mut t);
            buf.push(t);
        }
        self.peak_resident.fetch_max(buf.len(), Ordering::Relaxed);
    }
}

impl RecordSource for SegmentSource {
    fn len(&self) -> usize {
        self.n_records
    }

    fn for_each(&self, range: Range<usize>, f: &mut dyn FnMut(usize, &Itemset)) {
        if range.is_empty() {
            return;
        }
        assert!(range.end <= self.n_records, "range {range:?} beyond {} records", self.n_records);
        let mut buf: Vec<Itemset> = Vec::new();
        let first_block = range.start / self.block_lines;
        let last_block = (range.end - 1) / self.block_lines;
        for b in first_block..=last_block {
            self.decode_block(b, &mut buf);
            let block_start = b * self.block_lines;
            // Corrupt-store policy: a block holding fewer records than the
            // manifest implies must fail loudly, never silently undercount.
            let expected = self.block_lines.min(self.n_records - block_start);
            assert_eq!(
                buf.len(),
                expected,
                "segment store {:?}: block {b} holds {} records, manifest implies {expected} — \
                 delete the store directory and regenerate",
                self.dir,
                buf.len(),
            );
            let lo = range.start.max(block_start) - block_start;
            let hi = range.end.min(block_start + expected) - block_start;
            for (i, r) in buf[lo..hi].iter().enumerate() {
                f(block_start + lo + i, r);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("mrapriori_segment_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn write_store(dir: &Path, n: usize, block_lines: usize) -> SegmentSource {
        let mut w = SegmentWriter::create(dir, "demo", block_lines).unwrap();
        for i in 0..n {
            w.push(&vec![i as u32 % 7, 10 + i as u32 % 3]).unwrap();
        }
        w.finish().unwrap()
    }

    #[test]
    fn roundtrip_preserves_records() {
        let dir = tmp("roundtrip");
        let src = write_store(&dir, 25, 10);
        assert_eq!(src.len(), 25);
        assert_eq!(src.name(), "demo");
        assert_eq!(src.n_items(), 13); // max item 12
        let mut got = Vec::new();
        src.for_each(0..25, &mut |off, r| got.push((off, r.clone())));
        assert_eq!(got.len(), 25);
        for (i, (off, r)) in got.iter().enumerate() {
            assert_eq!(*off, i);
            let mut expect = vec![i as u32 % 7, 10 + i as u32 % 3];
            crate::itemset::canonicalize(&mut expect);
            assert_eq!(r, &expect, "record {i}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn blocks_on_disk_match_block_lines() {
        let dir = tmp("blocks");
        let src = write_store(&dir, 25, 10);
        assert_eq!(src.block_lines(), 10);
        // 3 block files: 10 + 10 + 5.
        for b in 0..3 {
            assert!(block_path(&dir, b).is_file(), "missing block {b}");
        }
        assert!(!block_path(&dir, 3).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resident_buffer_bounded_by_block() {
        let dir = tmp("bounded");
        let src = write_store(&dir, 100, 8);
        let mut n = 0;
        src.for_each(0..100, &mut |_, _| n += 1);
        assert_eq!(n, 100);
        assert!(src.peak_resident_records() <= 8, "peak {}", src.peak_resident_records());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn subrange_spanning_blocks() {
        let dir = tmp("subrange");
        let src = write_store(&dir, 30, 10);
        let mut offs = Vec::new();
        src.for_each(7..23, &mut |off, _| offs.push(off));
        assert_eq!(offs, (7..23).collect::<Vec<_>>());
        src.for_each(5..5, &mut |_, _| panic!("empty range must not visit"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn open_rejects_missing_manifest() {
        let dir = tmp("missing");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(!exists(&dir));
        assert!(matches!(open(&dir), Err(SegmentError::Io(_))));
        std::fs::write(dir.join(MANIFEST), "name x\nn_items 3\n").unwrap();
        assert!(exists(&dir));
        assert!(matches!(open(&dir), Err(SegmentError::BadManifest(_))));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_transactions_rejected() {
        let dir = tmp("empty-txn");
        let mut w = SegmentWriter::create(&dir, "x", 4).unwrap();
        w.push(&vec![1]).unwrap();
        assert!(matches!(w.push(&vec![]), Err(SegmentError::EmptyTransaction(1))));
        // Dropping an unfinished writer removes its staging directory and
        // never publishes anything.
        drop(w);
        assert!(!dir.exists(), "unfinished store must not be published");
        let parent = dir.parent().unwrap();
        let stem = dir.file_name().unwrap().to_str().unwrap().to_string();
        for entry in std::fs::read_dir(parent).unwrap() {
            let name = entry.unwrap().file_name().to_string_lossy().to_string();
            assert!(!name.starts_with(&format!("{stem}.partial")), "leaked staging dir {name}");
        }
    }

    #[test]
    fn finish_replaces_existing_store_atomically() {
        let dir = tmp("replace");
        let old = super::write_store(&dir, "v1", 5, 0, vec![vec![1u32, 2]]).unwrap();
        assert_eq!(old.len(), 1);
        // No partial state is ever visible at `dir`: while the second store
        // is being written, the published one still reads consistently.
        let w2 = {
            let mut w = SegmentWriter::create(&dir, "v2", 5).unwrap();
            for i in 0..7u32 {
                w.push(&vec![i]).unwrap();
            }
            let still = open(&dir).unwrap();
            assert_eq!(still.len(), 1, "published store must be intact mid-write");
            w
        };
        let new = w2.finish().unwrap();
        assert_eq!(new.len(), 7);
        assert_eq!(new.name(), "v2");
        assert_eq!(open(&dir).unwrap().len(), 7);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn append_continues_partial_block() {
        let dir = tmp("append-partial");
        // 13 records at block_lines 5: blocks 5 + 5 + 3 (partial).
        let src = write_store(&dir, 13, 5);
        let (n_items, rev) = (src.n_items(), src.manifest_rev());
        assert_eq!(rev, 13);
        assert_eq!(src.n_blocks(), 3);
        let mut w = SegmentWriter::append(&dir, n_items, 5).unwrap();
        for i in 13..23 {
            w.push(&vec![i as u32 % 7, 10 + i as u32 % 3]).unwrap();
        }
        let grown = w.finish().unwrap();
        assert_eq!(grown.len(), 23);
        assert_eq!(grown.name(), "demo");
        assert_eq!(grown.n_items(), n_items);
        assert_eq!(grown.n_blocks(), 5);
        // The grown partial block is re-enumerated; record offsets stay
        // exact through for_each.
        assert_eq!(grown.blocks_since(rev), 2..5);
        assert_eq!(grown.blocks_since(10), 2..5);
        assert_eq!(grown.blocks_since(0), 0..5);
        assert_eq!(grown.blocks_since(23), 5..5);
        let mut got = Vec::new();
        grown.for_each(0..23, &mut |off, r| got.push((off, r.clone())));
        for (i, (off, r)) in got.iter().enumerate() {
            assert_eq!(*off, i);
            let mut expect = vec![i as u32 % 7, 10 + i as u32 % 3];
            crate::itemset::canonicalize(&mut expect);
            assert_eq!(r, &expect, "record {i}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn append_at_block_boundary_starts_fresh_block() {
        let dir = tmp("append-boundary");
        let src = write_store(&dir, 10, 5);
        let mut w = SegmentWriter::append(&dir, src.n_items(), 5).unwrap();
        w.push(&vec![1u32, 2]).unwrap();
        let grown = w.finish().unwrap();
        assert_eq!(grown.len(), 11);
        assert_eq!(grown.n_blocks(), 3);
        assert_eq!(grown.blocks_since(10), 2..3);
        let mut n = 0;
        grown.for_each(0..11, &mut |_, _| n += 1);
        assert_eq!(n, 11);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn append_rejects_mismatched_shape() {
        let dir = tmp("append-mismatch");
        let src = write_store(&dir, 10, 5);
        let n_items = src.n_items();
        assert!(matches!(
            SegmentWriter::append(&dir, n_items, 4),
            Err(SegmentError::AppendMismatch { field: "block_lines", existing: 5, requested: 4 })
        ));
        assert!(matches!(
            SegmentWriter::append(&dir, n_items + 1, 5),
            Err(SegmentError::AppendMismatch { field: "n_items", .. })
        ));
        // A rejected (or dropped) append leaves the published store as-is.
        assert_eq!(open(&dir).unwrap().len(), 10);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dropped_append_leaves_store_untouched() {
        let dir = tmp("append-drop");
        let src = write_store(&dir, 7, 5);
        let mut w = SegmentWriter::append(&dir, src.n_items(), 5).unwrap();
        w.push(&vec![3u32]).unwrap();
        drop(w);
        let still = open(&dir).unwrap();
        assert_eq!(still.len(), 7);
        let mut n = 0;
        still.for_each(0..7, &mut |_, _| n += 1);
        assert_eq!(n, 7);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_store_roundtrips() {
        let dir = tmp("empty");
        let w = SegmentWriter::create(&dir, "none", 4).unwrap();
        let src = w.finish().unwrap();
        assert_eq!(src.len(), 0);
        assert!(src.is_empty());
        src.for_each(0..0, &mut |_, _| panic!("no records"));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
