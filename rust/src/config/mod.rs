//! Typed configuration: experiment settings loadable from TOML-subset files
//! (see `configs/*.toml`), covering the cluster topology, cost-model
//! weights, overheads, and per-run mining parameters.

use crate::cluster::{ClusterConfig, CostWeights, NodeSpec, OverheadParams};
use crate::util::tomlmini::Doc;
use std::path::Path;

#[derive(Debug)]
/// Failures loading a configuration file.
pub enum ConfigError {
    /// The file could not be read.
    Io {
        /// Path that failed.
        path: String,
        /// Underlying error.
        source: std::io::Error,
    },
    /// The file is not valid TOML-subset syntax.
    Parse(crate::util::tomlmini::ParseError),
    /// The file parsed but holds inconsistent settings.
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io { path, source } => write!(f, "io error reading {path}: {source}"),
            ConfigError::Parse(e) => std::fmt::Display::fmt(e, f),
            ConfigError::Invalid(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<crate::util::tomlmini::ParseError> for ConfigError {
    fn from(e: crate::util::tomlmini::ParseError) -> Self {
        ConfigError::Parse(e)
    }
}

/// Load a full cluster configuration from a TOML file. Missing keys fall
/// back to [`ClusterConfig::paper_cluster`] defaults.
pub fn load_cluster(path: &Path) -> Result<ClusterConfig, ConfigError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| ConfigError::Io { path: path.display().to_string(), source: e })?;
    cluster_from_doc(&Doc::parse(&text)?)
}

/// Build a cluster config from a parsed document.
pub fn cluster_from_doc(doc: &Doc) -> Result<ClusterConfig, ConfigError> {
    let mut cfg = ClusterConfig::paper_cluster();

    if let Some(n) = doc.int("cluster.data_nodes") {
        let map_slots = doc.int("cluster.map_slots_per_node").unwrap_or(4) as usize;
        cfg = ClusterConfig::uniform(n as usize, map_slots);
    }
    if let Some(speeds) = doc.get("cluster.node_speeds").and_then(|v| v.as_array()) {
        if speeds.len() != cfg.nodes.len() {
            return Err(ConfigError::Invalid(format!(
                "node_speeds has {} entries for {} nodes",
                speeds.len(),
                cfg.nodes.len()
            )));
        }
        for (node, s) in cfg.nodes.iter_mut().zip(speeds) {
            node.speed = s
                .as_float()
                .ok_or_else(|| ConfigError::Invalid("node_speeds must be numeric".into()))?;
            if node.speed <= 0.0 {
                return Err(ConfigError::Invalid("node speed must be positive".into()));
            }
        }
    }
    if let Some(r) = doc.int("cluster.reducers") {
        cfg.n_reducers = r.max(1) as usize;
    }
    if let Some(w) = doc.int("cluster.workers") {
        cfg.workers = w.max(1) as usize;
    }

    // Overheads.
    let oh = &mut cfg.overhead;
    if let Some(v) = doc.float("overhead.job_submit") {
        oh.job_submit = v;
    }
    if let Some(v) = doc.float("overhead.task_start") {
        oh.task_start = v;
    }
    if let Some(v) = doc.float("overhead.nonlocal_penalty") {
        oh.nonlocal_penalty = v;
    }
    if let Some(v) = doc.float("overhead.driver_gap") {
        oh.driver_gap = v;
    }

    // Cost weights.
    let set_weight = |key: &str, slot: &mut f64| -> Result<(), ConfigError> {
        if let Some(v) = doc.float(key) {
            if v < 0.0 {
                return Err(ConfigError::Invalid(format!("{key} must be >= 0")));
            }
            *slot = v;
        }
        Ok(())
    };
    let w = &mut cfg.weights;
    set_weight("weights.record", &mut w.record)?;
    set_weight("weights.map_tuple", &mut w.map_tuple)?;
    set_weight("weights.join_pair", &mut w.join_pair)?;
    set_weight("weights.prune_check", &mut w.prune_check)?;
    set_weight("weights.cand_built", &mut w.cand_built)?;
    set_weight("weights.subset_visit", &mut w.subset_visit)?;
    set_weight("weights.bitmap_word", &mut w.bitmap_word)?;
    set_weight("weights.triangle_update", &mut w.triangle_update)?;
    set_weight("weights.combine_tuple", &mut w.combine_tuple)?;
    set_weight("weights.shuffle_tuple", &mut w.shuffle_tuple)?;
    set_weight("weights.reduce_tuple", &mut w.reduce_tuple)?;
    Ok(cfg)
}

/// Render a cluster configuration back to the TOML subset (round-trips
/// through [`cluster_from_doc`]; used by `mrapriori calibrate --emit`).
pub fn render_cluster(cfg: &ClusterConfig) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(s, "[cluster]");
    let uniform = cfg.nodes.iter().all(|n| (n.speed - 1.0).abs() < 1e-12);
    let _ = writeln!(s, "data_nodes = {}", cfg.nodes.len());
    let _ = writeln!(
        s,
        "map_slots_per_node = {}",
        cfg.nodes.first().map(|n| n.map_slots).unwrap_or(4)
    );
    if !uniform {
        let speeds: Vec<String> = cfg.nodes.iter().map(|n| format!("{}", n.speed)).collect();
        let _ = writeln!(s, "node_speeds = [{}]", speeds.join(", "));
    }
    let _ = writeln!(s, "reducers = {}", cfg.n_reducers);
    let _ = writeln!(s, "workers = {}", cfg.workers);
    let oh = &cfg.overhead;
    let _ = writeln!(s, "\n[overhead]");
    let _ = writeln!(s, "job_submit = {}", oh.job_submit);
    let _ = writeln!(s, "task_start = {}", oh.task_start);
    let _ = writeln!(s, "nonlocal_penalty = {}", oh.nonlocal_penalty);
    let _ = writeln!(s, "driver_gap = {}", oh.driver_gap);
    let w = &cfg.weights;
    let _ = writeln!(s, "\n[weights]");
    let _ = writeln!(s, "record = {:e}", w.record);
    let _ = writeln!(s, "map_tuple = {:e}", w.map_tuple);
    let _ = writeln!(s, "join_pair = {:e}", w.join_pair);
    let _ = writeln!(s, "prune_check = {:e}", w.prune_check);
    let _ = writeln!(s, "cand_built = {:e}", w.cand_built);
    let _ = writeln!(s, "subset_visit = {:e}", w.subset_visit);
    let _ = writeln!(s, "bitmap_word = {:e}", w.bitmap_word);
    let _ = writeln!(s, "triangle_update = {:e}", w.triangle_update);
    let _ = writeln!(s, "combine_tuple = {:e}", w.combine_tuple);
    let _ = writeln!(s, "shuffle_tuple = {:e}", w.shuffle_tuple);
    let _ = writeln!(s, "reduce_tuple = {:e}", w.reduce_tuple);
    s
}

/// Keep NodeSpec public-API discoverable from this module too.
pub type Node = NodeSpec;
/// Alias of [`CostWeights`].
pub type Weights = CostWeights;
/// Alias of [`OverheadParams`].
pub type Overheads = OverheadParams;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_empty() {
        let cfg = cluster_from_doc(&Doc::parse("").unwrap()).unwrap();
        assert_eq!(cfg.nodes.len(), 4);
        assert_eq!(cfg.overhead.job_submit, 15.0);
    }

    #[test]
    fn overrides_apply() {
        let text = r#"
[cluster]
data_nodes = 2
map_slots_per_node = 8
reducers = 3
workers = 2

[overhead]
job_submit = 7.5

[weights]
subset_visit = 1e-7
bitmap_word = 2e-7
"#;
        let cfg = cluster_from_doc(&Doc::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.nodes.len(), 2);
        assert_eq!(cfg.nodes[0].map_slots, 8);
        assert_eq!(cfg.n_reducers, 3);
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.overhead.job_submit, 7.5);
        assert_eq!(cfg.weights.subset_visit, 1e-7);
        assert_eq!(cfg.weights.bitmap_word, 2e-7);
        // Untouched weights keep their defaults.
        assert_eq!(cfg.weights.join_pair, CostWeights::default().join_pair);
        assert_eq!(cfg.weights.triangle_update, CostWeights::default().triangle_update);
    }

    #[test]
    fn node_speeds_validated() {
        let bad = "[cluster]\ndata_nodes = 2\nnode_speeds = [1.0, 1.0, 1.0]";
        assert!(cluster_from_doc(&Doc::parse(bad).unwrap()).is_err());
        let bad = "[cluster]\ndata_nodes = 1\nnode_speeds = [-1.0]";
        assert!(cluster_from_doc(&Doc::parse(bad).unwrap()).is_err());
        let ok = "[cluster]\ndata_nodes = 2\nnode_speeds = [1.0, 1.5]";
        let cfg = cluster_from_doc(&Doc::parse(ok).unwrap()).unwrap();
        assert_eq!(cfg.nodes[1].speed, 1.5);
    }

    #[test]
    fn negative_weight_rejected() {
        let bad = "[weights]\nrecord = -1.0";
        assert!(cluster_from_doc(&Doc::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn render_roundtrips() {
        let mut cfg = ClusterConfig::uniform(3, 2);
        cfg.overhead.job_submit = 9.0;
        cfg.weights.subset_visit = 3.3e-6;
        let text = render_cluster(&cfg);
        let back = cluster_from_doc(&Doc::parse(&text).unwrap()).unwrap();
        assert_eq!(back.nodes.len(), 3);
        assert_eq!(back.overhead.job_submit, 9.0);
        assert!((back.weights.subset_visit - 3.3e-6).abs() < 1e-18);
    }
}
